//! Request queues + batching policy (pure logic, tested without PJRT).
//!
//! The dispatcher maintains one FIFO queue per kernel context *per
//! tenant lane*, indexed by dense [`KernelId`] and [`TenantId`] — names
//! are interned once at ingress, so a push moves a `u32` and a small
//! `Copy` token, never a `String`. (The original map-keyed design also
//! leaked: an empty per-kernel queue stayed resident forever once its
//! name had been seen. The dense layout is bounded by registry size ×
//! tenant count by construction; each queue's ring buffer keeps its
//! high-water capacity — bounded by `depth` entries of a few words each
//! — for the engine's life, and is freed when the engine drops.)
//!
//! Since the completion-slab refactor (DESIGN.md §10) a queue entry is
//! a [`Queued`] — an enqueue timestamp plus an opaque token (a slab
//! [`RowSpan`](super::completion::RowSpan) in production). Request
//! *inputs* live in the slab slot, not the queue, so pushing a request
//! moves a handful of words and the steady-state submit path performs
//! no heap allocation at all. Workers refill a reused buffer through
//! [`QueueSet::take_batch_into`], so dispatch allocates nothing per
//! batch either.
//!
//! Tokens are **spans** ([`SpanToken`]): one entry can carry many
//! contiguous rows of a single slab slot, so a whole-batch submit
//! enqueues *one* entry regardless of row count. Accounting (`depth`,
//! quotas, [`QueueSet::queued_for`], `total_queued`) is therefore in
//! **rows**, not entries, and [`QueueSet::take_batch_into`] splits an
//! oversized front span at the row budget: the taken head rides out
//! with this worker while the remainder stays at the queue front for
//! the next idle worker — this is how one 64k-row batch fans out across
//! the whole worker pool and recombines in the slab by row index.
//!
//! ## Multi-tenant admission and fairness (DESIGN.md §13)
//!
//! Every push is attributed to a **tenant lane**. Admission enforces
//! two bounds and both are checked before anything is mutated: the
//! tenant's row **quota** (its private share of queue memory) and the
//! original per-kernel **depth** (the global bound, preserved so the
//! fabric's backlog stays bounded no matter how many tenants exist).
//! A request refused by either bound is handed back to the caller —
//! the service layer turns that into a typed `Rejected { tenant, … }`.
//!
//! Batch selection runs **weighted deficit round-robin over lanes**,
//! layered on the per-kernel steal-score policy *within* the chosen
//! lane. Lanes with queued work sit in a ring; the front lane's deficit
//! is replenished to `weight × max_batch` rows when it reaches the
//! head, each take spends deficit row-for-row, and a lane that
//! exhausts its deficit rotates to the back. A saturating tenant
//! therefore gets exactly its weighted share of takes while light
//! tenants' rows never wait behind more than one round of heavier
//! lanes — a greedy tenant cannot starve a polite one.
//!
//! The pick is **O(active tenants + non-empty kernels in the chosen
//! lane)**: empty lanes leave the ring eagerly, and each lane keeps a
//! dense list of its non-empty kernels so the steal-score scan (rows +
//! age bonus, unchanged from the single-tenant design) never iterates
//! the whole registry. This is the hoisted accounting that replaced
//! the old full-registry rebuild on every `take_batch_into`.
//!
//! Workers (overlay pipelines) still pick with **context affinity**
//! inside the chosen lane: a worker holding kernel K's context prefers
//! K's queue — switching contexts is cheap on this overlay (sub-µs,
//! the paper's headline) but never free, and affinity also models the
//! BRAM-resident data staging of Fig. 4. Affinity never overrides the
//! lane choice: fairness ranks above context reuse.

use crate::exec::KernelId;
use std::collections::VecDeque;
use std::time::Instant;

/// Dense tenant index, interned by the service layer alongside kernel
/// names. Index 0 is always the default tenant (anonymous/loopback
/// traffic when auth is off).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct TenantId(pub u32);

impl TenantId {
    /// The catch-all lane for unauthenticated traffic.
    pub(crate) const DEFAULT: TenantId = TenantId(0);

    pub(crate) fn index(self) -> usize {
        self.0 as usize
    }
}

/// A queue token that carries one or more contiguous rows and can be
/// split at a row boundary. Splitting is what lets a worker take a
/// partial batch while the remainder stays queued for its peers.
pub(crate) trait SpanToken {
    /// Rows this token carries (always ≥ 1 for queued tokens).
    fn rows(&self) -> usize;

    /// Split off the first `n` rows (0 < `n` < `self.rows()`) as a new
    /// token, leaving `self` holding the remainder.
    fn take_front(&mut self, n: usize) -> Self;
}

/// Single-row tokens for queue-policy tests: one row, never split.
#[cfg(test)]
impl SpanToken for u32 {
    fn rows(&self) -> usize {
        1
    }

    fn take_front(&mut self, _n: usize) -> Self {
        unreachable!("single-row tokens are never split")
    }
}

/// One queued request span: when it arrived, an optional absolute
/// deadline, and the token that locates its inputs and completion slot
/// (a reply channel would be an allocation; a slab span is three
/// words).
#[derive(Debug, Clone, Copy)]
pub(crate) struct Queued<T> {
    pub(crate) enqueued: Instant,
    /// Absolute budget boundary: a span still queued past it is
    /// **expired** at take time (handed to the caller to fail typed)
    /// instead of executed — stale work never reaches a backend.
    pub(crate) deadline: Option<Instant>,
    pub(crate) token: T,
}

/// One tenant's private slice of the queue set: per-kernel FIFOs, row
/// accounting, its DRR weight/deficit, and its admission quota.
#[derive(Debug)]
struct Lane<T> {
    queues: Vec<VecDeque<Queued<T>>>,
    /// Queued rows per kernel within this lane.
    kernel_rows: Vec<usize>,
    /// Dense, unordered list of kernels with queued entries — the
    /// steal-score scan walks this instead of the whole registry.
    nonempty: Vec<u32>,
    /// Total rows queued in this lane.
    queued: usize,
    weight: u64,
    quota: usize,
    /// Remaining DRR row budget while this lane sits at the ring head.
    deficit: u64,
    in_ring: bool,
}

impl<T> Lane<T> {
    fn new(n_kernels: usize, weight: u64, quota: usize) -> Self {
        Lane {
            queues: (0..n_kernels).map(|_| VecDeque::new()).collect(),
            kernel_rows: vec![0; n_kernels],
            nonempty: Vec::new(),
            queued: 0,
            weight,
            quota,
            deficit: 0,
            in_ring: false,
        }
    }
}

/// Per-kernel, per-tenant FIFO queues, dense over the kernel registry
/// and the tenant table. Each kernel is bounded globally at `depth`
/// **rows** and each tenant lane at its own quota.
#[derive(Debug)]
pub(crate) struct QueueSet<T> {
    lanes: Vec<Lane<T>>,
    /// DRR ring of lane indices with queued work, served front-first.
    ring: VecDeque<u32>,
    /// Queued rows per kernel across every lane (the global bound).
    rows: Vec<usize>,
    depth: usize,
    /// Total rows queued across every kernel and lane.
    pub(crate) total_queued: usize,
}

impl<T: SpanToken> QueueSet<T> {
    /// Single-tenant set: one default lane with an unbounded quota,
    /// so only the global per-kernel depth binds — byte-for-byte the
    /// pre-tenant admission behavior.
    pub(crate) fn new(n_kernels: usize, depth: usize) -> Self {
        Self::with_tenants(n_kernels, depth, &[(1, usize::MAX)])
    }

    /// One lane per `(weight, quota)` tenant entry, index-aligned with
    /// the service layer's tenant table (entry 0 is the default lane).
    pub(crate) fn with_tenants(n_kernels: usize, depth: usize, tenants: &[(u32, usize)]) -> Self {
        assert!(depth >= 1, "queue depth must be positive");
        assert!(!tenants.is_empty(), "at least the default tenant");
        for &(weight, quota) in tenants {
            assert!(weight >= 1, "tenant weight must be positive");
            assert!(quota >= 1, "tenant quota must be positive");
        }
        Self {
            lanes: tenants
                .iter()
                .map(|&(weight, quota)| Lane::new(n_kernels, u64::from(weight), quota))
                .collect(),
            ring: VecDeque::new(),
            rows: vec![0; n_kernels],
            depth,
            total_queued: 0,
        }
    }

    pub(crate) fn n_kernels(&self) -> usize {
        self.rows.len()
    }

    /// Per-kernel admission bound, in rows (global across tenants).
    pub(crate) fn depth(&self) -> usize {
        self.depth
    }

    /// Rows queued by `tenant` across every kernel (what quota
    /// admission compares to [`QueueSet::tenant_quota`]).
    pub(crate) fn tenant_queued(&self, tenant: TenantId) -> usize {
        self.lanes[tenant.index()].queued
    }

    /// `tenant`'s admission quota, in rows.
    pub(crate) fn tenant_quota(&self, tenant: TenantId) -> usize {
        self.lanes[tenant.index()].quota
    }

    /// Default-lane push — the single-tenant API, kept for the policy
    /// tests and any caller that predates tenancy.
    #[cfg(test)]
    pub(crate) fn try_push(&mut self, kernel: KernelId, q: Queued<T>) -> Result<(), Queued<T>> {
        self.try_push_for(TenantId::DEFAULT, kernel, q)
    }

    /// Enqueue one request span for `tenant`, or hand it back when
    /// admitting its rows would breach either the tenant's quota or
    /// the kernel's global depth (the admission-control path). Both
    /// bounds are checked before any state changes, so a refused push
    /// is a true no-op. `kernel` and `tenant` must come from the
    /// registry/table this set was sized for.
    pub(crate) fn try_push_for(
        &mut self,
        tenant: TenantId,
        kernel: KernelId,
        q: Queued<T>,
    ) -> Result<(), Queued<T>> {
        let n = q.token.rows();
        debug_assert!(n > 0, "zero-row spans are completed at reserve time");
        let lane = &mut self.lanes[tenant.index()];
        if lane.queued + n > lane.quota || self.rows[kernel.index()] + n > self.depth {
            return Err(q);
        }
        if lane.kernel_rows[kernel.index()] == 0 {
            lane.nonempty.push(kernel.0);
        }
        lane.queues[kernel.index()].push_back(q);
        lane.kernel_rows[kernel.index()] += n;
        lane.queued += n;
        if !lane.in_ring {
            lane.in_ring = true;
            self.ring.push_back(tenant.0);
        }
        self.rows[kernel.index()] += n;
        self.total_queued += n;
        Ok(())
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.total_queued == 0
    }

    /// Rows queued for `kernel` across every tenant (what global
    /// admission compares to `depth`).
    pub(crate) fn queued_for(&self, kernel: KernelId) -> usize {
        self.rows[kernel.index()]
    }

    /// Batching policy, two levels. **Lane**: weighted deficit
    /// round-robin — the ring's front lane is served until its deficit
    /// (replenished to `weight × max_batch` rows on arrival at the
    /// head) runs dry, then rotates to the back; lanes that empty
    /// leave the ring. **Kernel within the lane**: prefer the worker's
    /// current context if it has work there; otherwise the lane's
    /// non-empty kernel with the highest (rows + age bonus) score.
    /// Takes up to `min(max_batch, deficit)` **rows** FIFO into `out`
    /// (cleared first), which the worker reuses across batches —
    /// dispatch performs no per-batch allocation in steady state.
    ///
    /// An entry whose span exceeds the remaining row budget is
    /// **split**: the head rides out with this take, the remainder
    /// stays at the queue front — so the next worker (or the next
    /// iteration of this one) picks up where this take stopped, and
    /// one oversized batch fans out across every idle worker.
    ///
    /// **Lazy expiry**: a front span whose deadline has passed by
    /// `now` is popped whole into `expired` (cleared first) instead of
    /// `out` — it spends no deficit and no batch budget, and the
    /// caller fails it typed without executing. A take may therefore
    /// return `Some` with an empty `out` when everything it
    /// encountered was stale.
    ///
    /// Returns the chosen kernel and the tenant whose lane it came
    /// from, or `None` when nothing is queued.
    pub(crate) fn take_batch_into(
        &mut self,
        current_context: Option<KernelId>,
        max_batch: usize,
        now: Instant,
        out: &mut Vec<Queued<T>>,
        expired: &mut Vec<Queued<T>>,
    ) -> Option<(KernelId, TenantId)> {
        out.clear();
        expired.clear();
        if self.is_empty() {
            return None;
        }
        // Empty lanes leave the ring eagerly on take, so the front is
        // always serviceable; the loop is defensive, not load-bearing.
        let lane_idx = loop {
            let li = *self.ring.front()? as usize;
            if self.lanes[li].queued > 0 {
                break li;
            }
            self.ring.pop_front();
            self.lanes[li].in_ring = false;
        };
        let lane = &mut self.lanes[lane_idx];
        if lane.deficit == 0 {
            lane.deficit = lane.weight * max_batch as u64;
        }
        // cast-ok: deficit starts ≤ weight×max_batch and only shrinks,
        // so min(max_batch as u64, deficit) fits back in usize.
        let budget = (max_batch as u64).min(lane.deficit) as usize;

        let kernel = match current_context {
            Some(k) if lane.kernel_rows[k.index()] > 0 => k,
            _ => {
                let score = |i: usize| {
                    let age_ms = now
                        .duration_since(lane.queues[i].front().unwrap().enqueued)
                        .as_secs_f64()
                        * 1e3;
                    lane.kernel_rows[i] as f64 + age_ms * 0.1
                };
                lane.nonempty
                    .iter()
                    // total_cmp: scores are finite here, but a NaN-safe
                    // total order costs nothing and cannot panic.
                    .max_by(|&&a, &&b| score(a as usize).total_cmp(&score(b as usize)))
                    .map(|&i| KernelId(i))?
            }
        };
        let q = &mut lane.queues[kernel.index()];
        let mut taken = 0usize;
        let mut stale = 0usize;
        while taken < budget {
            let Some(front) = q.front_mut() else { break };
            // Lazy expiry: a dead span leaves whole (its deadline
            // covers every row) and costs no deficit.
            if front.deadline.map_or(false, |d| d <= now) {
                stale += front.token.rows();
                expired.push(q.pop_front().unwrap());
                continue;
            }
            let span_rows = front.token.rows();
            debug_assert!(span_rows > 0, "zero-row span in queue");
            if span_rows <= budget - taken {
                taken += span_rows;
                out.push(q.pop_front().unwrap());
            } else {
                let head = Queued {
                    enqueued: front.enqueued,
                    deadline: front.deadline,
                    token: front.token.take_front(budget - taken),
                };
                taken = budget;
                out.push(head);
            }
        }
        let removed = taken + stale;
        lane.kernel_rows[kernel.index()] -= removed;
        if lane.kernel_rows[kernel.index()] == 0 {
            let pos = lane
                .nonempty
                .iter()
                .position(|&i| i == kernel.0)
                .expect("drained kernel is tracked as non-empty");
            lane.nonempty.swap_remove(pos);
        }
        lane.queued -= removed;
        lane.deficit -= taken as u64;
        if lane.queued == 0 {
            lane.in_ring = false;
            lane.deficit = 0;
            self.ring.pop_front();
        } else if lane.deficit == 0 {
            let front = self.ring.pop_front().expect("served lane was at front");
            self.ring.push_back(front);
        }
        self.rows[kernel.index()] -= removed;
        self.total_queued -= removed;
        // cast-ok: lane indices come from the ring, which only holds
        // indices of the lanes vec (sized from a u32-indexed table).
        Some((kernel, TenantId(lane_idx as u32)))
    }

    /// Remove every queued span matching `pred` — the cancellation
    /// path: a `Cancel` evicts a request's still-queued rows so they
    /// never reach a backend. Accounting (lane rows, quotas, kernel
    /// depths, `total_queued`) is fixed up in place; a lane emptied
    /// here stays in the DRR ring and is popped defensively at the
    /// next take. Returns rows removed.
    pub(crate) fn purge(&mut self, mut pred: impl FnMut(&T) -> bool) -> usize {
        let mut removed = 0usize;
        for lane in &mut self.lanes {
            for ki in 0..lane.queues.len() {
                if lane.kernel_rows[ki] == 0 {
                    continue;
                }
                let mut rows_gone = 0usize;
                lane.queues[ki].retain(|e| {
                    if pred(&e.token) {
                        rows_gone += e.token.rows();
                        false
                    } else {
                        true
                    }
                });
                if rows_gone == 0 {
                    continue;
                }
                lane.kernel_rows[ki] -= rows_gone;
                if lane.kernel_rows[ki] == 0 {
                    let pos = lane
                        .nonempty
                        .iter()
                        .position(|&i| i as usize == ki)
                        .expect("purged kernel is tracked as non-empty");
                    lane.nonempty.swap_remove(pos);
                }
                lane.queued -= rows_gone;
                self.rows[ki] -= rows_gone;
                self.total_queued -= rows_gone;
                removed += rows_gone;
            }
        }
        removed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: KernelId = KernelId(0);
    const B: KernelId = KernelId(1);
    const C: KernelId = KernelId(2);

    fn pend(token: u32) -> Queued<u32> {
        Queued {
            enqueued: Instant::now(),
            deadline: None,
            token,
        }
    }

    fn take<T: SpanToken>(
        qs: &mut QueueSet<T>,
        ctx: Option<KernelId>,
        max: usize,
    ) -> Option<(KernelId, Vec<Queued<T>>)> {
        let mut out = Vec::new();
        let mut expired = Vec::new();
        let (k, _tenant) = qs.take_batch_into(ctx, max, Instant::now(), &mut out, &mut expired)?;
        assert!(expired.is_empty(), "deadline-free spans never expire");
        Some((k, out))
    }

    /// A splittable test span mirroring the production `RowSpan` shape.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    struct Span {
        id: u32,
        row: u32,
        len: u32,
    }

    impl SpanToken for Span {
        fn rows(&self) -> usize {
            self.len as usize
        }

        fn take_front(&mut self, n: usize) -> Span {
            assert!(n > 0 && n < self.len as usize);
            let head = Span {
                id: self.id,
                row: self.row,
                len: n as u32,
            };
            self.row += n as u32;
            self.len -= n as u32;
            head
        }
    }

    fn span(id: u32, row: u32, len: u32) -> Queued<Span> {
        Queued {
            enqueued: Instant::now(),
            deadline: None,
            token: Span { id, row, len },
        }
    }

    /// A span whose deadline already passed when it was enqueued.
    fn dead_span(id: u32, row: u32, len: u32) -> Queued<Span> {
        Queued {
            enqueued: Instant::now(),
            deadline: Some(Instant::now()),
            token: Span { id, row, len },
        }
    }

    #[test]
    fn affinity_preferred_when_context_has_work() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        // Worker holds A: takes A despite B being longer.
        let (kernel, items) = take(&mut qs, Some(A), 16).unwrap();
        assert_eq!(kernel, A);
        assert_eq!(items.len(), 1);
    }

    #[test]
    fn steals_longest_queue_without_affinity() {
        let mut qs = QueueSet::new(3, 16);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(B, pend(2)).unwrap();
        qs.try_push(B, pend(3)).unwrap();
        let (kernel, items) = take(&mut qs, Some(C), 16).unwrap();
        assert_eq!(kernel, B);
        assert_eq!(items.len(), 2);
        assert_eq!(qs.total_queued, 1);
    }

    #[test]
    fn steal_weighs_rows_not_entries() {
        // One 8-row span must outweigh three single-row entries: the
        // policy measures queued work in rows.
        let mut qs = QueueSet::new(2, 64);
        qs.try_push(A, span(0, 0, 8)).unwrap();
        for i in 0..3 {
            qs.try_push(B, span(1, i, 1)).unwrap();
        }
        let (kernel, items) = take(&mut qs, None, 64).unwrap();
        assert_eq!(kernel, A);
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].token.rows(), 8);
    }

    #[test]
    fn respects_max_batch_fifo_and_reuses_the_buffer() {
        let mut qs = QueueSet::new(1, 16);
        for i in 0..10 {
            qs.try_push(A, pend(i)).unwrap();
        }
        let mut out = Vec::new();
        let mut exp = Vec::new();
        qs.take_batch_into(None, 4, Instant::now(), &mut out, &mut exp).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].token, 0);
        assert_eq!(out[3].token, 3);
        assert_eq!(qs.queued_for(A), 6);
        // The same buffer serves the next batch: cleared, not leaked.
        qs.take_batch_into(None, 4, Instant::now(), &mut out, &mut exp).unwrap();
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].token, 4);
    }

    #[test]
    fn oversized_span_splits_across_successive_takes() {
        // One 10-row span, workers taking 4 rows at a time: each take
        // carries a consecutive head while the tail stays queued —
        // the cross-worker fan-out of a single big batch.
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(A, span(7, 0, 10)).unwrap();
        assert_eq!(qs.queued_for(A), 10);
        let (_, t1) = take(&mut qs, None, 4).unwrap();
        assert_eq!(t1.len(), 1);
        assert_eq!(t1[0].token, Span { id: 7, row: 0, len: 4 });
        assert_eq!(qs.queued_for(A), 6);
        let (_, t2) = take(&mut qs, None, 4).unwrap();
        assert_eq!(t2[0].token, Span { id: 7, row: 4, len: 4 });
        let (_, t3) = take(&mut qs, None, 4).unwrap();
        assert_eq!(t3[0].token, Span { id: 7, row: 8, len: 2 });
        assert!(qs.is_empty());
        assert!(take(&mut qs, None, 4).is_none());
    }

    #[test]
    fn take_pops_whole_spans_then_splits_the_last() {
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(A, span(1, 0, 3)).unwrap();
        qs.try_push(A, span(2, 0, 5)).unwrap();
        // Budget 6: the whole first span plus a 3-row head of the
        // second; the second's 2-row tail stays at the front.
        let (_, items) = take(&mut qs, None, 6).unwrap();
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].token, Span { id: 1, row: 0, len: 3 });
        assert_eq!(items[1].token, Span { id: 2, row: 0, len: 3 });
        assert_eq!(qs.queued_for(A), 2);
        let (_, rest) = take(&mut qs, None, 6).unwrap();
        assert_eq!(rest[0].token, Span { id: 2, row: 3, len: 2 });
    }

    #[test]
    fn depth_counts_rows_not_entries() {
        let mut qs = QueueSet::new(1, 8);
        qs.try_push(A, span(1, 0, 5)).unwrap();
        // 5 + 4 > 8: refused, handed back intact.
        let back = qs.try_push(A, span(2, 0, 4)).unwrap_err();
        assert_eq!(back.token, Span { id: 2, row: 0, len: 4 });
        qs.try_push(A, span(3, 0, 3)).unwrap();
        assert_eq!(qs.queued_for(A), 8);
        assert_eq!(qs.total_queued, 8);
    }

    #[test]
    fn empty_returns_none() {
        let mut qs: QueueSet<u32> = QueueSet::new(2, 16);
        assert!(take(&mut qs, None, 8).is_none());
    }

    #[test]
    fn depth_limit_rejects_and_hands_back() {
        let mut qs = QueueSet::new(2, 2);
        assert_eq!(qs.depth(), 2);
        qs.try_push(A, pend(1)).unwrap();
        qs.try_push(A, pend(2)).unwrap();
        // A is full: the request comes back untouched.
        let rejected = qs.try_push(A, pend(3)).unwrap_err();
        assert_eq!(rejected.token, 3);
        assert_eq!(qs.queued_for(A), 2);
        assert_eq!(qs.total_queued, 2);
        // Other queues still admit (the bound is per kernel).
        qs.try_push(B, pend(4)).unwrap();
        // Draining a batch frees capacity again.
        take(&mut qs, Some(A), 1).unwrap();
        qs.try_push(A, pend(5)).unwrap();
        assert_eq!(qs.queued_for(A), 2);
    }

    #[test]
    // Backdates entries with wall-clock Instant arithmetic; the
    // scheduling policy itself is covered by the clock-free tests.
    #[cfg_attr(miri, ignore)]
    fn age_bonus_prevents_starvation() {
        let mut qs = QueueSet::new(2, 16);
        let old = Instant::now() - std::time::Duration::from_millis(500);
        qs.try_push(
            A, // starved
            Queued {
                enqueued: old,
                deadline: None,
                token: 0u32,
            },
        )
        .unwrap();
        for i in 0..3 {
            qs.try_push(B, pend(i)).unwrap(); // busy
        }
        // 0.1/ms * 500ms = 50 > 3: the old queue wins.
        let (kernel, _) = take(&mut qs, None, 8).unwrap();
        assert_eq!(kernel, A);
    }

    #[test]
    fn high_water_burst_drains_through_take_batch_into() {
        // The shutdown path drains by repeated take_batch_into (the
        // workers' loop), not a dedicated drain call — a burst must
        // come back out completely through the same door.
        let mut qs = QueueSet::new(2, 1024);
        for i in 0..512 {
            qs.try_push(A, pend(i)).unwrap();
        }
        qs.try_push(B, pend(999)).unwrap();
        let mut out = Vec::new();
        let mut exp = Vec::new();
        let mut drained = 0;
        while let Some(_k) = qs.take_batch_into(None, 64, Instant::now(), &mut out, &mut exp) {
            drained += out.len();
        }
        assert_eq!(drained, 513);
        assert!(qs.is_empty());
        // The set stays usable afterwards.
        qs.try_push(B, pend(1)).unwrap();
        assert_eq!(qs.queued_for(B), 1);
    }

    // ── Tenant lanes: quotas + weighted deficit round-robin ─────────

    const T0: TenantId = TenantId(0);
    const T1: TenantId = TenantId(1);

    #[test]
    fn drr_pick_order_is_pinned_for_a_known_table() {
        // Weight 2 vs weight 1, one kernel, 24 vs 12 queued rows,
        // max_batch 4. DRR must serve the heavy lane two full batches
        // per round and the light lane one: 0,0,1, 0,0,1, 0,0,1 —
        // deterministic, no clocks involved, both lanes drain dry on
        // the same round.
        let mut qs: QueueSet<u32> = QueueSet::with_tenants(1, 64, &[(2, 64), (1, 64)]);
        for i in 0..12 {
            qs.try_push_for(T0, A, pend(i)).unwrap();
            qs.try_push_for(T0, A, pend(50 + i)).unwrap();
            qs.try_push_for(T1, A, pend(100 + i)).unwrap();
        }
        let mut order = Vec::new();
        let mut out = Vec::new();
        let mut exp = Vec::new();
        while let Some((_k, tenant)) = qs.take_batch_into(None, 4, Instant::now(), &mut out, &mut exp) {
            assert_eq!(out.len(), 4, "every take drains a full batch here");
            order.push(tenant.0);
        }
        assert_eq!(order, vec![0, 0, 1, 0, 0, 1, 0, 0, 1]);
        assert!(qs.is_empty());
    }

    #[test]
    fn weighted_lanes_drain_proportionally_under_saturation() {
        // Both lanes saturated: after any whole number of DRR rounds
        // the heavy lane has drained twice the rows of the light one.
        let mut qs: QueueSet<u32> = QueueSet::with_tenants(1, 1024, &[(2, 512), (1, 512)]);
        for i in 0..300 {
            qs.try_push_for(T0, A, pend(i)).unwrap();
            qs.try_push_for(T1, A, pend(1000 + i)).unwrap();
        }
        let mut drained = [0usize; 2];
        let mut out = Vec::new();
        let mut exp = Vec::new();
        for _ in 0..9 {
            let (_k, t) = qs
                .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
                .unwrap();
            drained[t.index()] += out.len();
        }
        // 9 takes = 3 whole rounds of (heavy, heavy, light).
        assert_eq!(drained[0], 48);
        assert_eq!(drained[1], 24);
    }

    #[test]
    fn tenant_quota_rejects_without_touching_other_lanes() {
        let mut qs: QueueSet<u32> = QueueSet::with_tenants(1, 16, &[(1, 16), (1, 2)]);
        qs.try_push_for(T1, A, pend(1)).unwrap();
        qs.try_push_for(T1, A, pend(2)).unwrap();
        // T1's quota (2 rows) is full: handed back, nothing mutated.
        let back = qs.try_push_for(T1, A, pend(3)).unwrap_err();
        assert_eq!(back.token, 3);
        assert_eq!(qs.tenant_queued(T1), 2);
        assert_eq!(qs.tenant_quota(T1), 2);
        // The default lane still admits against the global depth.
        for i in 0..14 {
            qs.try_push_for(T0, A, pend(10 + i)).unwrap();
        }
        assert_eq!(qs.queued_for(A), 16);
    }

    #[test]
    fn global_depth_holds_across_lanes() {
        // Per-kernel depth is global: two tenants with roomy quotas
        // still cannot queue more than `depth` rows for one kernel.
        let mut qs: QueueSet<u32> = QueueSet::with_tenants(1, 8, &[(1, 8), (1, 8)]);
        for i in 0..5 {
            qs.try_push_for(T0, A, pend(i)).unwrap();
        }
        for i in 0..3 {
            qs.try_push_for(T1, A, pend(10 + i)).unwrap();
        }
        let back = qs.try_push_for(T1, A, pend(99)).unwrap_err();
        assert_eq!(back.token, 99);
        assert_eq!(qs.queued_for(A), 8);
        assert_eq!(qs.tenant_queued(T1), 3);
    }

    #[test]
    fn light_lane_is_never_starved_by_a_flooding_one() {
        // A greedy lane with 500 queued rows and a polite lane with 4:
        // the polite lane's rows surface within two DRR rounds, not
        // after the flood drains.
        let mut qs: QueueSet<u32> = QueueSet::with_tenants(1, 1024, &[(1, 1000), (1, 16)]);
        for i in 0..500 {
            qs.try_push_for(T0, A, pend(i)).unwrap();
        }
        for i in 0..4 {
            qs.try_push_for(T1, A, pend(9000 + i)).unwrap();
        }
        let mut out = Vec::new();
        let mut exp = Vec::new();
        let mut takes_until_polite = 0;
        loop {
            let (_k, t) = qs
                .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
                .unwrap();
            takes_until_polite += 1;
            if t == T1 {
                break;
            }
        }
        assert!(
            takes_until_polite <= 2,
            "polite lane waited {takes_until_polite} takes behind the flood"
        );
    }

    #[test]
    fn lane_deficit_carries_across_partial_takes() {
        // A lane whose chosen kernel runs dry mid-budget keeps the
        // ring head and spends its remaining deficit on its other
        // kernel before rotating — the deficit is per lane, not per
        // take.
        let mut qs: QueueSet<u32> = QueueSet::with_tenants(2, 64, &[(1, 64), (1, 64)]);
        for i in 0..3 {
            qs.try_push_for(T0, A, pend(i)).unwrap();
        }
        for i in 0..8 {
            qs.try_push_for(T0, B, pend(10 + i)).unwrap();
        }
        qs.try_push_for(T1, A, pend(99)).unwrap();
        let mut out = Vec::new();
        let mut exp = Vec::new();
        // Affinity steers the first take to kernel A, which runs dry
        // at 3 of the 8-row deficit: the lane keeps the ring head.
        let (k, t) = qs
            .take_batch_into(Some(A), 8, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert_eq!((k, t), (A, T0));
        assert_eq!(out.len(), 3);
        // Remaining deficit (5) caps the next take from the same lane.
        let (k, t) = qs
            .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert_eq!((k, t), (B, T0));
        assert_eq!(out.len(), 5);
        // Deficit spent: the lane rotated behind T1.
        let (k, t) = qs
            .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert_eq!((k, t), (A, T1));
        assert_eq!(out.len(), 1);
        let (k, t) = qs
            .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert_eq!((k, t), (B, T0));
        assert_eq!(out.len(), 3);
        assert!(qs.is_empty());
    }

    // ── Lazy expiry + cancellation purge ────────────────────────────

    #[test]
    fn expired_spans_surface_at_take_without_spending_budget() {
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(A, dead_span(1, 0, 3)).unwrap();
        qs.try_push(A, span(2, 0, 4)).unwrap();
        qs.try_push(A, dead_span(3, 0, 2)).unwrap();
        qs.try_push(A, span(4, 0, 4)).unwrap();
        assert_eq!(qs.queued_for(A), 13);
        let mut out = Vec::new();
        let mut exp = Vec::new();
        // Budget 8: both dead spans pop into `expired` for free, both
        // live spans fill the batch.
        let (k, _) = qs
            .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert_eq!(k, A);
        assert_eq!(
            out.iter().map(|q| q.token.id).collect::<Vec<_>>(),
            vec![2, 4]
        );
        assert_eq!(
            exp.iter().map(|q| q.token.id).collect::<Vec<_>>(),
            vec![1, 3]
        );
        // Expired rows left the accounting too: nothing queued.
        assert!(qs.is_empty());
        assert_eq!(qs.queued_for(A), 0);
    }

    #[test]
    fn all_expired_take_returns_some_with_empty_out() {
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(A, dead_span(1, 0, 5)).unwrap();
        qs.try_push(A, dead_span(2, 0, 5)).unwrap();
        let mut out = Vec::new();
        let mut exp = Vec::new();
        let got = qs.take_batch_into(None, 4, Instant::now(), &mut out, &mut exp);
        assert_eq!(got, Some((A, TenantId::DEFAULT)));
        assert!(out.is_empty(), "nothing executable was taken");
        assert_eq!(exp.len(), 2);
        assert!(qs.is_empty());
        // The set stays serviceable afterwards.
        qs.try_push(A, span(3, 0, 1)).unwrap();
        let (k, items) = take(&mut qs, None, 4).unwrap();
        assert_eq!(k, A);
        assert_eq!(items.len(), 1);
    }

    #[test]
    fn future_deadlines_do_not_expire() {
        let mut qs = QueueSet::new(1, 64);
        qs.try_push(
            A,
            Queued {
                enqueued: Instant::now(),
                deadline: Some(Instant::now() + std::time::Duration::from_secs(60)),
                token: Span { id: 1, row: 0, len: 2 },
            },
        )
        .unwrap();
        let (k, items) = take(&mut qs, None, 8).unwrap();
        assert_eq!(k, A);
        assert_eq!(items.len(), 1);
        // A split head inherits the deadline of its parent span.
        qs.try_push(
            A,
            Queued {
                enqueued: Instant::now(),
                deadline: Some(Instant::now() + std::time::Duration::from_secs(60)),
                token: Span { id: 2, row: 0, len: 6 },
            },
        )
        .unwrap();
        let mut out = Vec::new();
        let mut exp = Vec::new();
        qs.take_batch_into(None, 4, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert!(out[0].deadline.is_some(), "split head keeps the deadline");
        assert!(exp.is_empty());
    }

    #[test]
    fn purge_removes_matching_spans_with_full_accounting() {
        let mut qs: QueueSet<Span> = QueueSet::with_tenants(2, 64, &[(1, 64), (1, 64)]);
        qs.try_push_for(T0, A, span(1, 0, 3)).unwrap();
        qs.try_push_for(T0, B, span(1, 3, 2)).unwrap();
        qs.try_push_for(T0, A, span(2, 0, 4)).unwrap();
        qs.try_push_for(T1, A, span(3, 0, 5)).unwrap();
        assert_eq!(qs.total_queued, 14);
        // Cancel request 1: both its spans leave, everything else stays.
        let removed = qs.purge(|t| t.id == 1);
        assert_eq!(removed, 5);
        assert_eq!(qs.total_queued, 9);
        assert_eq!(qs.queued_for(A), 9);
        assert_eq!(qs.queued_for(B), 0);
        assert_eq!(qs.tenant_queued(T0), 4);
        assert_eq!(qs.tenant_queued(T1), 5);
        // Purging a token nobody holds is a no-op.
        assert_eq!(qs.purge(|t| t.id == 77), 0);
        // The survivors still drain normally through the DRR ring
        // (including the lane/kernel purge emptied).
        let mut drained = 0;
        while let Some((_k, items)) = take(&mut qs, None, 64) {
            drained += items.iter().map(|q| q.token.rows()).sum::<usize>();
        }
        assert_eq!(drained, 9);
        assert!(qs.is_empty());
    }

    #[test]
    fn purge_that_empties_a_lane_leaves_the_ring_serviceable() {
        let mut qs: QueueSet<Span> = QueueSet::with_tenants(1, 64, &[(1, 64), (1, 64)]);
        qs.try_push_for(T0, A, span(1, 0, 4)).unwrap();
        qs.try_push_for(T1, A, span(2, 0, 4)).unwrap();
        // Empty T0's lane entirely; its stale ring slot must not wedge
        // or misattribute the next take.
        assert_eq!(qs.purge(|t| t.id == 1), 4);
        let mut out = Vec::new();
        let mut exp = Vec::new();
        let (k, t) = qs
            .take_batch_into(None, 8, Instant::now(), &mut out, &mut exp)
            .unwrap();
        assert_eq!((k, t), (A, T1));
        assert_eq!(out.len(), 1);
        assert!(qs.is_empty());
    }
}
