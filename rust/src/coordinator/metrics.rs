//! Serving metrics: wall-clock latency/throughput plus the *simulated
//! fabric timeline* (what the overlay hardware would have spent, using
//! the paper's II/latency/context-switch models at 300 MHz).

use crate::util::stats::Samples;
use std::collections::BTreeMap;
use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: u64,
    pub batches: u64,
    pub batch_size_sum: u64,
    pub context_switches: u64,
    pub latency_us: Samples,
    pub queue_wait_us: Samples,
    pub per_kernel: BTreeMap<String, u64>,
    /// Simulated overlay fabric time (µs at 300 MHz), incl. switches.
    pub fabric_busy_us: f64,
    /// Simulated time spent on context switching only.
    pub fabric_switch_us: f64,
    pub wall: Duration,
}

impl Metrics {
    pub fn record_batch(
        &mut self,
        kernel: &str,
        n: usize,
        switched: bool,
        switch_us: f64,
        exec_us_sim: f64,
    ) {
        self.batches += 1;
        self.batch_size_sum += n as u64;
        self.completed += n as u64;
        *self.per_kernel.entry(kernel.to_string()).or_default() += n as u64;
        if switched {
            self.context_switches += 1;
            self.fabric_switch_us += switch_us;
            self.fabric_busy_us += switch_us;
        }
        self.fabric_busy_us += exec_us_sim;
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    pub fn render(&mut self) -> String {
        let wall_s = self.wall.as_secs_f64().max(1e-9);
        let mut s = String::new();
        s.push_str(&format!(
            "requests completed:   {} in {:.3}s ({:.0} req/s wall)\n",
            self.completed,
            wall_s,
            self.completed as f64 / wall_s
        ));
        s.push_str(&format!(
            "batches:              {} (mean size {:.1})\n",
            self.batches,
            self.mean_batch_size()
        ));
        s.push_str(&format!(
            "context switches:     {} ({:.2} us simulated switch time total)\n",
            self.context_switches, self.fabric_switch_us
        ));
        s.push_str(&format!(
            "simulated fabric busy: {:.1} us ({:.2}% of wall)\n",
            self.fabric_busy_us,
            self.fabric_busy_us / (wall_s * 1e6) * 100.0
        ));
        if !self.latency_us.is_empty() {
            s.push_str(&format!("request latency:      {}\n", self.latency_us.summary("us")));
        }
        if !self.queue_wait_us.is_empty() {
            s.push_str(&format!("queue wait:           {}\n", self.queue_wait_us.summary("us")));
        }
        s.push_str("per-kernel requests:  ");
        s.push_str(
            &self
                .per_kernel
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(" "),
        );
        s.push('\n');
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_batches() {
        let mut m = Metrics::default();
        m.record_batch("a", 4, true, 0.27, 1.0);
        m.record_batch("a", 2, false, 0.0, 0.5);
        assert_eq!(m.completed, 6);
        assert_eq!(m.batches, 2);
        assert_eq!(m.context_switches, 1);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        assert!((m.fabric_busy_us - 1.77).abs() < 1e-9);
    }

    #[test]
    fn renders() {
        let mut m = Metrics::default();
        m.wall = Duration::from_millis(100);
        m.record_batch("k", 8, true, 0.2, 3.0);
        m.latency_us.push(120.0);
        let s = m.render();
        assert!(s.contains("requests completed:   8"));
        assert!(s.contains("k=8"));
    }
}
