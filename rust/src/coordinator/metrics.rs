//! Raw serving counters: wall-clock latency/throughput plus the
//! *simulated fabric timeline* (what the overlay hardware would have
//! spent, using the paper's II/latency/context-switch models at
//! 300 MHz).
//!
//! This is the engine-side accumulator only. The client-facing, typed
//! view — percentiles computed, JSON-serializable, rendered for the
//! CLI — is `crate::service::MetricsSnapshot`, built from a
//! [`RawMetrics`] snapshot.
//!
//! Two-tier layout, shaped for the hot path:
//!
//! * the plain counters (`completed`, `rejected`, `failed`, ...) are
//!   **atomics** — rejections on the submit path and `completed()`
//!   probes never touch a lock;
//! * the heavyweight state (latency sample buffers, per-kernel
//!   traffic, fabric-time floats) lives behind one mutex taken **once
//!   per executed batch**, never per request;
//! * [`Metrics::raw_snapshot`] copies the raw sample buffers out under
//!   that lock and returns immediately — the clone-and-**sort** that
//!   percentile computation needs happens on the caller's thread,
//!   outside the lock, so a `GetMetrics` poll over the wire can never
//!   stall workers mid-batch (previously the full sort ran under the
//!   metrics lock on every snapshot).
//!
//! Per-kernel traffic is a dense `Vec<u64>` indexed by
//! [`KernelId`] — recording a batch bumps one integer instead of
//! allocating a `String` key for a map (the last per-batch allocation
//! on the worker's reply path). Per-tenant accounting follows the
//! same dense pattern, indexed by [`TenantId`]: each tenant carries
//! its own admitted/rejected/completed/failed ledger (the fairness
//! suite asserts `admitted == completed + failed` per tenant after a
//! drain) plus a latency sample buffer for per-tenant percentiles —
//! the observable half of the DRR fairness guarantee.

use super::queue::TenantId;
use crate::exec::KernelId;
use crate::util::stats::Samples;
use crate::util::sync::LockExt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// EWMA weight on the previous estimate when folding in a new
/// service-rate sample (new = old·α + sample·(1−α)).
const SERVICE_RATE_ALPHA: f64 = 0.8;

/// Per-batch timing facts recorded alongside the counters.
#[derive(Debug, Clone, Copy)]
pub(crate) struct BatchTiming {
    /// Whether serving this batch cost a context switch.
    pub(crate) switched: bool,
    /// Simulated switch time (µs at 300 MHz), 0 when not switched.
    pub(crate) switch_us: f64,
    /// Simulated execution time for the batch (µs at 300 MHz).
    pub(crate) exec_us_sim: f64,
}

/// Heavyweight accumulator state, locked once per batch.
#[derive(Debug)]
struct Heavy {
    latency_us: Samples,
    queue_wait_us: Samples,
    /// Completed requests per kernel, dense by [`KernelId`].
    per_kernel: Vec<u64>,
    /// Reply latency per tenant, dense by [`TenantId`] — the fairness
    /// suite's per-tenant p99 comes from here.
    tenant_latency_us: Vec<Samples>,
    /// Simulated overlay fabric time (µs at 300 MHz), incl. switches.
    fabric_busy_us: f64,
    /// Simulated time spent on context switching only.
    fabric_switch_us: f64,
}

/// One tenant's admission ledger, all atomics (the submit path and
/// settlement probes never lock). Invariant after a drain:
/// `admitted == completed + failed + cancelled` (rejected and shed
/// requests were never admitted and appear only in their own
/// counters). `expired_in_queue` is a *view* onto `failed` — rows
/// whose deadline lapsed before a worker took them are failed typed
/// `DeadlineExceeded` and additionally counted here.
#[derive(Debug)]
struct TenantLedger {
    admitted: AtomicU64,
    rejected: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    /// Queued rows removed by an explicit `Cancel` before any worker
    /// took them (rows already mid-execution settle as `completed`
    /// into an abandoned slot instead).
    cancelled: AtomicU64,
    /// Subset of `failed`: rows that expired waiting in the queue and
    /// never reached a backend.
    expired_in_queue: AtomicU64,
    /// Requests refused at admission because the estimated queue wait
    /// already exceeded their deadline budget (never admitted; a
    /// sibling of `rejected`, kept separate so capacity rejections and
    /// deadline sheds stay distinguishable).
    shed_at_admission: AtomicU64,
}

impl TenantLedger {
    fn new() -> TenantLedger {
        TenantLedger {
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            expired_in_queue: AtomicU64::new(0),
            shed_at_admission: AtomicU64::new(0),
        }
    }
}

/// The engine's shared metrics accumulator.
#[derive(Debug)]
pub(crate) struct Metrics {
    completed: AtomicU64,
    /// Requests refused by admission control (bounded queues/quotas).
    rejected: AtomicU64,
    /// Admitted requests whose execution failed (replied `Err`).
    failed: AtomicU64,
    batches: AtomicU64,
    batch_size_sum: AtomicU64,
    context_switches: AtomicU64,
    /// Heap allocations observed inside the workers' take→execute→
    /// reply window (excluding this accumulator's own sample pushes).
    /// Zero in steady state — the bench hard-asserts it.
    worker_allocs: AtomicU64,
    /// Queued rows removed by explicit `Cancel` (ledger term: see
    /// [`TenantLedger`]).
    cancelled: AtomicU64,
    /// Rows failed `DeadlineExceeded` at take time, subset of `failed`.
    expired_in_queue: AtomicU64,
    /// Requests shed at admission for an infeasible deadline budget.
    shed_at_admission: AtomicU64,
    /// Per-tenant ledgers, dense by [`TenantId`].
    tenants: Vec<TenantLedger>,
    /// Per-kernel service-rate EWMA (µs of wall time per row, f64
    /// bits), dense by [`KernelId`]. 0-bits means "no sample yet" —
    /// admission feasibility skips the check rather than shedding on a
    /// guess. Updated racily (load/blend/store) by workers; the
    /// estimate tolerates a lost sample.
    service_rate_us: Vec<AtomicU64>,
    heavy: Mutex<Heavy>,
}

impl Metrics {
    /// Sized by the kernel registry and the tenant table (both dense).
    pub(crate) fn new(n_kernels: usize, n_tenants: usize) -> Metrics {
        assert!(n_tenants >= 1, "at least the default tenant");
        Metrics {
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_size_sum: AtomicU64::new(0),
            context_switches: AtomicU64::new(0),
            worker_allocs: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            expired_in_queue: AtomicU64::new(0),
            shed_at_admission: AtomicU64::new(0),
            tenants: (0..n_tenants).map(|_| TenantLedger::new()).collect(),
            service_rate_us: (0..n_kernels).map(|_| AtomicU64::new(0)).collect(),
            heavy: Mutex::new(Heavy {
                latency_us: Samples::new(),
                queue_wait_us: Samples::new(),
                per_kernel: vec![0; n_kernels],
                tenant_latency_us: (0..n_tenants).map(|_| Samples::new()).collect(),
                fabric_busy_us: 0.0,
                fabric_switch_us: 0.0,
            }),
        }
    }

    /// Record one executed batch of `n` requests: counters (atomic),
    /// then one lock for the sample pushes and fabric accounting. A
    /// batch is tenant-affine by construction (it came out of one DRR
    /// lane), so one [`TenantId`] covers every row. `waits_us` yields
    /// the per-request enqueue→reply latency.
    pub(crate) fn record_batch(
        &self,
        kernel: KernelId,
        tenant: TenantId,
        n: usize,
        timing: BatchTiming,
        waits_us: impl Iterator<Item = f64>,
    ) {
        // relaxed-ok: batches/batch_size_sum are rate statistics; no
        // reader infers cross-thread state from them.
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_size_sum.fetch_add(n as u64, Ordering::Relaxed);
        // Ledger counter: `completed` participates in the
        // admitted == completed + failed settlement invariant that
        // shutdown/drain probes check from other threads, so the bump
        // publishes (Release) and probes observe (Acquire).
        self.completed.fetch_add(n as u64, Ordering::Release);
        // Ledger counter: per-tenant settlement, same contract.
        self.tenants[tenant.index()]
            .completed
            .fetch_add(n as u64, Ordering::Release);
        if timing.switched {
            // relaxed-ok: reporting statistic only.
            self.context_switches.fetch_add(1, Ordering::Relaxed);
        }
        let mut h = self.heavy.lock_unpoisoned();
        h.per_kernel[kernel.index()] += n as u64;
        if timing.switched {
            h.fabric_switch_us += timing.switch_us;
            h.fabric_busy_us += timing.switch_us;
        }
        h.fabric_busy_us += timing.exec_us_sim;
        let Heavy {
            latency_us,
            queue_wait_us,
            tenant_latency_us,
            ..
        } = &mut *h;
        let tenant_latency = &mut tenant_latency_us[tenant.index()];
        for wait in waits_us {
            latency_us.push(wait);
            tenant_latency.push(wait);
            queue_wait_us.push(wait - timing.exec_us_sim.min(wait));
        }
    }

    /// Count `n` requests admitted past both bounds for `tenant`
    /// (lock-free — this sits on the submit path). The per-tenant
    /// ledger's debit side: everything admitted must eventually land
    /// in `completed` or `failed`.
    pub(crate) fn record_admitted(&self, tenant: TenantId, n: u64) {
        // Ledger counter (see `completed`): settlement probes read it
        // cross-thread, so publish with Release.
        self.tenants[tenant.index()]
            .admitted
            .fetch_add(n, Ordering::Release);
    }

    /// Count `n` admission-control rejections for `tenant` (lock-free
    /// — this sits on the submit path).
    pub(crate) fn record_rejected(&self, tenant: TenantId, n: u64) {
        // Ledger counter (see `completed`): settlement probes read it
        // cross-thread, so publish with Release.
        self.rejected.fetch_add(n, Ordering::Release);
        self.tenants[tenant.index()]
            .rejected
            .fetch_add(n, Ordering::Release);
    }

    /// Count `n` admitted requests of `tenant` that failed in
    /// execution. Kept separate from [`Self::record_batch`] so failed
    /// requests appear in exactly one counter
    /// (`admitted == completed + failed`) and never as a phantom
    /// zero-size batch.
    pub(crate) fn record_failed(&self, tenant: TenantId, n: u64) {
        // Ledger counter (see `completed`): settlement probes read it
        // cross-thread, so publish with Release.
        self.failed.fetch_add(n, Ordering::Release);
        self.tenants[tenant.index()]
            .failed
            .fetch_add(n, Ordering::Release);
    }

    /// Count `n` queued rows of `tenant` removed by an explicit
    /// `Cancel` before execution. Third settlement term:
    /// `admitted == completed + failed + cancelled`.
    pub(crate) fn record_cancelled(&self, tenant: TenantId, n: u64) {
        // Ledger counter (see `completed`): settlement probes read it
        // cross-thread, so publish with Release.
        self.cancelled.fetch_add(n, Ordering::Release);
        self.tenants[tenant.index()]
            .cancelled
            .fetch_add(n, Ordering::Release);
    }

    /// Count `n` rows of `tenant` whose deadline lapsed in the queue.
    /// Callers pair this with [`Self::record_failed`] — expiry *is* a
    /// failure; this counter just names the cause.
    pub(crate) fn record_expired(&self, tenant: TenantId, n: u64) {
        // Ledger counter (see `completed`): settlement probes read it
        // cross-thread, so publish with Release.
        self.expired_in_queue.fetch_add(n, Ordering::Release);
        self.tenants[tenant.index()]
            .expired_in_queue
            .fetch_add(n, Ordering::Release);
    }

    /// Count `n` requests of `tenant` shed at admission because their
    /// deadline budget could not cover the estimated queue wait.
    pub(crate) fn record_shed(&self, tenant: TenantId, n: u64) {
        // Ledger counter (see `completed`): settlement probes read it
        // cross-thread, so publish with Release.
        self.shed_at_admission.fetch_add(n, Ordering::Release);
        self.tenants[tenant.index()]
            .shed_at_admission
            .fetch_add(n, Ordering::Release);
    }

    /// Fold one measured service-rate sample (wall µs per row) for
    /// `kernel` into the EWMA the admission feasibility check reads.
    pub(crate) fn record_service_rate(&self, kernel: KernelId, us_per_row: f64) {
        if !us_per_row.is_finite() || us_per_row <= 0.0 {
            return;
        }
        let cell = &self.service_rate_us[kernel.index()];
        // relaxed-ok: advisory estimate; a torn/lost blend only skews
        // the shed heuristic, never a ledger.
        let old = f64::from_bits(cell.load(Ordering::Relaxed));
        let new = if old == 0.0 {
            us_per_row
        } else {
            old * SERVICE_RATE_ALPHA + us_per_row * (1.0 - SERVICE_RATE_ALPHA)
        };
        // relaxed-ok: advisory estimate, see above.
        cell.store(new.to_bits(), Ordering::Relaxed);
    }

    /// Current service-rate estimate for `kernel` (wall µs per row),
    /// 0.0 until the first executed batch provides a sample.
    pub(crate) fn service_rate_us(&self, kernel: KernelId) -> f64 {
        // relaxed-ok: advisory estimate (see `record_service_rate`).
        f64::from_bits(self.service_rate_us[kernel.index()].load(Ordering::Relaxed))
    }

    /// Count `n` heap allocations observed on a worker's dispatch path
    /// (lock-free; recorded once per batch, usually with `n == 0`).
    pub(crate) fn record_worker_allocs(&self, n: u64) {
        if n > 0 {
            // relaxed-ok: audit statistic, read after workers join.
            self.worker_allocs.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Worker dispatch-path allocations so far (lock-free probe).
    pub(crate) fn worker_allocs(&self) -> u64 {
        // relaxed-ok: audit statistic, read after workers join.
        self.worker_allocs.load(Ordering::Relaxed)
    }

    /// Requests completed so far (lock-free probe).
    pub(crate) fn completed(&self) -> u64 {
        self.completed.load(Ordering::Acquire)
    }

    /// Rejections so far (lock-free probe).
    pub(crate) fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Acquire)
    }

    /// Copy everything out. The heavy lock is held only for the
    /// buffer copies — sorting/percentiles happen on the snapshot,
    /// on the caller's thread. `wall` is filled in by the engine.
    pub(crate) fn raw_snapshot(&self) -> RawMetrics {
        let h = self.heavy.lock_unpoisoned();
        RawMetrics {
            // Ledger trio reads pair with the Release bumps above.
            completed: self.completed.load(Ordering::Acquire),
            rejected: self.rejected.load(Ordering::Acquire),
            failed: self.failed.load(Ordering::Acquire),
            cancelled: self.cancelled.load(Ordering::Acquire),
            expired_in_queue: self.expired_in_queue.load(Ordering::Acquire),
            shed_at_admission: self.shed_at_admission.load(Ordering::Acquire),
            // relaxed-ok: statistics; the heavy lock above already
            // fences this snapshot against record_batch.
            batches: self.batches.load(Ordering::Relaxed),
            batch_size_sum: self.batch_size_sum.load(Ordering::Relaxed),
            context_switches: self.context_switches.load(Ordering::Relaxed),
            worker_allocs: self.worker_allocs.load(Ordering::Relaxed),
            latency_us: h.latency_us.clone(),
            queue_wait_us: h.queue_wait_us.clone(),
            per_kernel: h.per_kernel.clone(),
            per_tenant: self
                .tenants
                .iter()
                .zip(h.tenant_latency_us.iter())
                .map(|(t, lat)| RawTenant {
                    // Ledger reads pair with the Release bumps above.
                    admitted: t.admitted.load(Ordering::Acquire),
                    rejected: t.rejected.load(Ordering::Acquire),
                    completed: t.completed.load(Ordering::Acquire),
                    failed: t.failed.load(Ordering::Acquire),
                    cancelled: t.cancelled.load(Ordering::Acquire),
                    expired_in_queue: t.expired_in_queue.load(Ordering::Acquire),
                    shed_at_admission: t.shed_at_admission.load(Ordering::Acquire),
                    latency_us: lat.clone(),
                })
                .collect(),
            fabric_busy_us: h.fabric_busy_us,
            fabric_switch_us: h.fabric_switch_us,
            wall: Duration::ZERO,
        }
    }
}

/// One tenant's detached ledger + latency samples, dense by
/// [`TenantId`] alongside the service layer's tenant-name table.
#[derive(Debug, Clone)]
pub(crate) struct RawTenant {
    pub(crate) admitted: u64,
    pub(crate) rejected: u64,
    pub(crate) completed: u64,
    pub(crate) failed: u64,
    pub(crate) cancelled: u64,
    pub(crate) expired_in_queue: u64,
    pub(crate) shed_at_admission: u64,
    pub(crate) latency_us: Samples,
}

/// A plain-data copy of the accumulator, detached from every lock.
/// The service layer turns this into its typed `MetricsSnapshot`.
#[derive(Debug, Clone)]
pub(crate) struct RawMetrics {
    pub(crate) completed: u64,
    pub(crate) rejected: u64,
    pub(crate) failed: u64,
    /// Queued rows removed by explicit `Cancel` before execution.
    pub(crate) cancelled: u64,
    /// Subset of `failed`: rows expired in the queue, never executed.
    pub(crate) expired_in_queue: u64,
    /// Requests shed at admission (infeasible deadline, never admitted).
    pub(crate) shed_at_admission: u64,
    pub(crate) batches: u64,
    pub(crate) batch_size_sum: u64,
    pub(crate) context_switches: u64,
    /// Heap allocations observed on worker dispatch paths (0 in
    /// steady state; see the bench's zero-alloc audit).
    pub(crate) worker_allocs: u64,
    pub(crate) latency_us: Samples,
    pub(crate) queue_wait_us: Samples,
    /// Completed requests per kernel, dense by [`KernelId`].
    pub(crate) per_kernel: Vec<u64>,
    /// Per-tenant ledgers + latency, dense by [`TenantId`].
    pub(crate) per_tenant: Vec<RawTenant>,
    pub(crate) fabric_busy_us: f64,
    pub(crate) fabric_switch_us: f64,
    pub(crate) wall: Duration,
}

impl RawMetrics {
    pub(crate) fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const T0: TenantId = TenantId(0);
    const T1: TenantId = TenantId(1);

    fn timing(switched: bool, switch_us: f64, exec_us_sim: f64) -> BatchTiming {
        BatchTiming {
            switched,
            switch_us,
            exec_us_sim,
        }
    }

    #[test]
    fn records_batches() {
        let m = Metrics::new(2, 1);
        m.record_batch(KernelId(0), T0, 4, timing(true, 0.27, 1.0), std::iter::empty());
        m.record_batch(KernelId(0), T0, 2, timing(false, 0.0, 0.5), std::iter::empty());
        let raw = m.raw_snapshot();
        assert_eq!(raw.completed, 6);
        assert_eq!(raw.batches, 2);
        assert_eq!(raw.context_switches, 1);
        assert_eq!(raw.per_kernel, vec![6, 0]);
        assert!((raw.mean_batch_size() - 3.0).abs() < 1e-12);
        assert!((raw.fabric_busy_us - 1.77).abs() < 1e-9);
    }

    #[test]
    fn records_rejections_and_failures() {
        let m = Metrics::new(1, 1);
        m.record_rejected(T0, 1);
        m.record_rejected(T0, 3);
        m.record_failed(T0, 2);
        let raw = m.raw_snapshot();
        assert_eq!(raw.rejected, 4);
        assert_eq!(m.rejected(), 4);
        assert_eq!(raw.failed, 2);
        // Neither path touches the success-side counters.
        assert_eq!(raw.completed, 0);
        assert_eq!(m.completed(), 0);
        assert_eq!(raw.batches, 0);
    }

    #[test]
    fn tenant_ledgers_are_independent_and_balance() {
        let m = Metrics::new(1, 2);
        // T0: 6 admitted → 4 completed + 2 failed; 3 rejected at the
        // door. T1: 2 admitted → 2 completed, nothing else.
        m.record_admitted(T0, 6);
        m.record_rejected(T0, 3);
        m.record_batch(KernelId(0), T0, 4, timing(false, 0.0, 1.0), [8.0; 4].into_iter());
        m.record_failed(T0, 2);
        m.record_admitted(T1, 2);
        m.record_batch(KernelId(0), T1, 2, timing(false, 0.0, 1.0), [3.0; 2].into_iter());
        let raw = m.raw_snapshot();
        let t0 = &raw.per_tenant[0];
        assert_eq!(
            (t0.admitted, t0.rejected, t0.completed, t0.failed),
            (6, 3, 4, 2)
        );
        assert_eq!(t0.admitted, t0.completed + t0.failed);
        let t1 = &raw.per_tenant[1];
        assert_eq!(
            (t1.admitted, t1.rejected, t1.completed, t1.failed),
            (2, 0, 2, 0)
        );
        // Per-tenant latency buffers are separate from the global one.
        assert_eq!(raw.latency_us.len(), 6);
        assert_eq!(raw.per_tenant[0].latency_us.len(), 4);
        assert_eq!(raw.per_tenant[1].latency_us.len(), 2);
        // Global counters are the sums.
        assert_eq!(raw.completed, 6);
        assert_eq!(raw.rejected, 3);
        assert_eq!(raw.failed, 2);
    }

    #[test]
    fn deadline_counters_extend_the_ledger() {
        let m = Metrics::new(1, 2);
        // T0: 10 admitted → 5 completed + 3 failed (2 of them queue
        // expiries) + 2 cancelled; 4 shed at the door.
        m.record_admitted(T0, 10);
        m.record_batch(KernelId(0), T0, 5, timing(false, 0.0, 1.0), std::iter::empty());
        m.record_failed(T0, 3);
        m.record_expired(T0, 2);
        m.record_cancelled(T0, 2);
        m.record_shed(T0, 4);
        let raw = m.raw_snapshot();
        let t0 = &raw.per_tenant[0];
        assert_eq!(t0.admitted, t0.completed + t0.failed + t0.cancelled);
        assert_eq!(
            (t0.cancelled, t0.expired_in_queue, t0.shed_at_admission),
            (2, 2, 4)
        );
        assert!(t0.expired_in_queue <= t0.failed);
        // Globals mirror the per-tenant sums; T1 stays untouched.
        assert_eq!(
            (raw.cancelled, raw.expired_in_queue, raw.shed_at_admission),
            (2, 2, 4)
        );
        let t1 = &raw.per_tenant[1];
        assert_eq!((t1.cancelled, t1.shed_at_admission), (0, 0));
    }

    #[test]
    fn service_rate_ewma_blends_and_ignores_junk() {
        let m = Metrics::new(2, 1);
        let k = KernelId(0);
        assert_eq!(m.service_rate_us(k), 0.0);
        m.record_service_rate(k, 10.0); // first sample adopted whole
        assert!((m.service_rate_us(k) - 10.0).abs() < 1e-9);
        m.record_service_rate(k, 20.0); // 10·0.8 + 20·0.2 = 12
        assert!((m.service_rate_us(k) - 12.0).abs() < 1e-9);
        m.record_service_rate(k, f64::NAN);
        m.record_service_rate(k, -5.0);
        m.record_service_rate(k, 0.0);
        assert!((m.service_rate_us(k) - 12.0).abs() < 1e-9);
        // Kernels are independent.
        assert_eq!(m.service_rate_us(KernelId(1)), 0.0);
    }

    #[test]
    fn worker_alloc_audit_accumulates() {
        let m = Metrics::new(1, 1);
        m.record_worker_allocs(0);
        assert_eq!(m.worker_allocs(), 0);
        m.record_worker_allocs(3);
        m.record_worker_allocs(2);
        assert_eq!(m.worker_allocs(), 5);
        assert_eq!(m.raw_snapshot().worker_allocs, 5);
    }

    #[test]
    fn waits_feed_both_distributions() {
        let m = Metrics::new(1, 1);
        // exec 3.0us: a 10us wait spent 7us queued; a 2us wait (reply
        // beat the model) clamps to 0 queue time, never negative.
        m.record_batch(
            KernelId(0),
            T0,
            2,
            timing(true, 0.2, 3.0),
            [10.0, 2.0].into_iter(),
        );
        let mut raw = m.raw_snapshot();
        let lat = raw.latency_us.summarize().unwrap();
        assert_eq!(lat.n, 2);
        assert!((lat.mean - 6.0).abs() < 1e-9);
        let qw = raw.queue_wait_us.summarize().unwrap();
        assert!((qw.max - 7.0).abs() < 1e-9);
        assert!((qw.min - 0.0).abs() < 1e-9);
    }

    #[test]
    fn snapshot_is_detached_from_the_accumulator() {
        let m = Metrics::new(1, 1);
        m.record_batch(KernelId(0), T0, 1, timing(false, 0.0, 1.0), [5.0].into_iter());
        let mut snap = m.raw_snapshot();
        // Sorting the snapshot (what percentile computation does)
        // must not disturb the live accumulator.
        let _ = snap.latency_us.summarize();
        m.record_batch(KernelId(0), T0, 1, timing(false, 0.0, 1.0), [1.0].into_iter());
        let raw2 = m.raw_snapshot();
        assert_eq!(raw2.completed, 2);
        assert_eq!(raw2.latency_us.len(), 2);
        assert_eq!(snap.latency_us.len(), 1);
    }
}
