//! Raw serving counters: wall-clock latency/throughput plus the
//! *simulated fabric timeline* (what the overlay hardware would have
//! spent, using the paper's II/latency/context-switch models at
//! 300 MHz).
//!
//! This is the engine-side accumulator only. The client-facing, typed
//! view — percentiles computed, JSON-serializable, rendered for the
//! CLI — is [`crate::service::MetricsSnapshot`], built from this
//! struct under the metrics lock.

use crate::util::stats::Samples;
use std::collections::BTreeMap;
use std::time::Duration;

#[derive(Debug, Default)]
pub struct Metrics {
    pub completed: u64,
    /// Requests refused by admission control (bounded queues).
    pub rejected: u64,
    /// Admitted requests whose execution failed (replied `Err`).
    pub failed: u64,
    pub batches: u64,
    pub batch_size_sum: u64,
    pub context_switches: u64,
    pub latency_us: Samples,
    pub queue_wait_us: Samples,
    pub per_kernel: BTreeMap<String, u64>,
    /// Simulated overlay fabric time (µs at 300 MHz), incl. switches.
    pub fabric_busy_us: f64,
    /// Simulated time spent on context switching only.
    pub fabric_switch_us: f64,
    pub wall: Duration,
}

impl Metrics {
    pub fn record_batch(
        &mut self,
        kernel: &str,
        n: usize,
        switched: bool,
        switch_us: f64,
        exec_us_sim: f64,
    ) {
        self.batches += 1;
        self.batch_size_sum += n as u64;
        self.completed += n as u64;
        *self.per_kernel.entry(kernel.to_string()).or_default() += n as u64;
        if switched {
            self.context_switches += 1;
            self.fabric_switch_us += switch_us;
            self.fabric_busy_us += switch_us;
        }
        self.fabric_busy_us += exec_us_sim;
    }

    /// Count `n` admission-control rejections.
    pub fn record_rejected(&mut self, n: u64) {
        self.rejected += n;
    }

    /// Count `n` admitted requests that failed in execution. Kept
    /// separate from [`Self::record_batch`] so failed requests appear
    /// in exactly one counter (`admitted == completed + failed`) and
    /// never as a phantom zero-size batch.
    pub fn record_failed(&mut self, n: u64) {
        self.failed += n;
    }

    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_batches() {
        let mut m = Metrics::default();
        m.record_batch("a", 4, true, 0.27, 1.0);
        m.record_batch("a", 2, false, 0.0, 0.5);
        assert_eq!(m.completed, 6);
        assert_eq!(m.batches, 2);
        assert_eq!(m.context_switches, 1);
        assert!((m.mean_batch_size() - 3.0).abs() < 1e-12);
        assert!((m.fabric_busy_us - 1.77).abs() < 1e-9);
    }

    #[test]
    fn records_rejections_and_failures() {
        let mut m = Metrics::default();
        m.record_rejected(1);
        m.record_rejected(3);
        m.record_failed(2);
        assert_eq!(m.rejected, 4);
        assert_eq!(m.failed, 2);
        // Neither path touches the success-side counters.
        assert_eq!(m.completed, 0);
        assert_eq!(m.batches, 0);
    }
}
