//! L3 serving engine (the software analogue of the paper's Fig. 4
//! system: ARM-side runtime managing hardware tasks on replicated
//! overlay pipelines).
//!
//! This module is **crate-private**: the public client surface is
//! [`crate::service`] (`OverlayService` / `KernelHandle`), which owns
//! an [`Engine`] and talks to it through the typed submit ports below.
//! Nothing outside the crate constructs an engine or pushes a request
//! directly.
//!
//! Architecture (std threads + channels; tokio is unavailable offline):
//!
//! * the service layer submits requests through [`Shared::submit`] /
//!   [`Shared::submit_batch`] as (dense [`KernelId`](exec::KernelId),
//!   input row) pairs — names were interned once when the client's
//!   `KernelHandle` was created, so nothing here allocates or compares
//!   strings;
//! * a shared [`queue::QueueSet`] holds **bounded** per-kernel FIFOs
//!   indexed by kernel id; a full queue refuses the request at the
//!   door ([`SubmitRejection::Full`]) — backpressure is explicit, not
//!   implicit queue growth;
//! * each **fabric worker** thread owns a `Box<dyn Backend>` — the
//!   interpreter, the tape-compiled turbo executor, the cycle-accurate
//!   overlay simulator, or the PJRT engine ([`crate::exec`]); backends
//!   are built inside the worker thread because the PJRT client is not
//!   `Send` (one worker ≙ one overlay pipeline replica);
//! * kernels are compiled **once** into a shared
//!   [`Arc<KernelRegistry>`](exec::KernelRegistry) owned by the
//!   service builder — schedule, timing, context image and op tape are
//!   never recomputed per worker;
//! * workers pull context-affine batches into a **reused
//!   [`FlatBatch`](exec::FlatBatch) buffer** — the request side of the
//!   dispatch loop performs no per-packet allocation in steady state
//!   (replies still cost one `Vec` each: the [`Reply`] channel
//!   contract hands each caller an owned row) — charge the modeled
//!   context switch cost when they change kernels, execute through
//!   their backend, and reply;
//! * [`Engine::shutdown`] **drains**: the flag stops admission, but
//!   workers keep taking batches until every queue is empty before
//!   exiting, so every admitted request gets its reply;
//! * metrics capture wall-clock latency plus the simulated 300 MHz
//!   fabric timeline (II model + context-switch model; the sim backend
//!   reports *measured* fabric cycles instead of the model).

pub mod metrics;
pub mod queue;

use crate::exec::{self, BackendKind, ExecError, FlatBatch, KernelId, KernelRegistry};
use crate::resources::SYSTEM_CLOCK_MHZ;
use anyhow::{Context, Result};
use metrics::Metrics;
use queue::{Pending, QueueSet};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

/// Completion message for one request. Engine-level errors speak
/// [`ExecError`]; the service layer converts to `ServiceError` at the
/// client boundary.
pub type Reply = Result<Vec<i32>, ExecError>;

type Token = mpsc::Sender<Reply>;

/// Why a submit was refused at the door (before any queueing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitRejection {
    /// The engine is shut down (or draining) — no new admissions.
    ShutDown,
    /// The kernel's queue is at its depth limit.
    Full { queued: usize, limit: usize },
}

/// State shared between the submit ports, the workers and the engine
/// handle. The service layer's `KernelHandle`s hold an `Arc<Shared>`,
/// which is what makes them `Clone + Send` sessions independent of the
/// `OverlayService` value itself.
pub struct Shared {
    queues: Mutex<QueueState>,
    cv: Condvar,
    metrics: Mutex<Metrics>,
}

struct QueueState {
    qs: QueueSet<Token>,
    shutdown: bool,
}

impl Shared {
    /// Submit one pre-validated request (shape checks happen in the
    /// service layer, which owns the kernel's arity). The reply arrives
    /// on the returned channel.
    pub fn submit(
        &self,
        id: KernelId,
        inputs: Vec<i32>,
    ) -> Result<mpsc::Receiver<Reply>, SubmitRejection> {
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.queues.lock().unwrap();
            if st.shutdown {
                return Err(SubmitRejection::ShutDown);
            }
            let pending = Pending {
                inputs,
                enqueued: Instant::now(),
                token: tx,
            };
            if st.qs.try_push(id, pending).is_err() {
                let queued = st.qs.queued_for(id);
                let limit = st.qs.depth();
                drop(st);
                self.metrics.lock().unwrap().record_rejected(1);
                return Err(SubmitRejection::Full { queued, limit });
            }
        }
        self.cv.notify_one();
        Ok(rx)
    }

    /// Submit a whole kernel-affine batch atomically: either every row
    /// is admitted (one receiver per row, in row order) or none is —
    /// a half-admitted batch would make `call_batch` semantics
    /// unobservable under backpressure.
    pub fn submit_batch(
        &self,
        id: KernelId,
        batch: &FlatBatch,
    ) -> Result<Vec<mpsc::Receiver<Reply>>, SubmitRejection> {
        let n = batch.n_rows();
        let mut rxs = Vec::with_capacity(n);
        {
            let mut st = self.queues.lock().unwrap();
            if st.shutdown {
                return Err(SubmitRejection::ShutDown);
            }
            let queued = st.qs.queued_for(id);
            let limit = st.qs.depth();
            if queued + n > limit {
                drop(st);
                self.metrics.lock().unwrap().record_rejected(n as u64);
                return Err(SubmitRejection::Full { queued, limit });
            }
            let now = Instant::now();
            for row in batch.iter() {
                let (tx, rx) = mpsc::channel();
                let pending = Pending {
                    inputs: row.to_vec(),
                    enqueued: now,
                    token: tx,
                };
                if st.qs.try_push(id, pending).is_err() {
                    unreachable!("batch admission capacity checked above");
                }
                rxs.push(rx);
            }
        }
        self.cv.notify_all();
        Ok(rxs)
    }

    /// Whether the engine has stopped admitting requests.
    pub fn is_shut_down(&self) -> bool {
        self.queues.lock().unwrap().shutdown
    }
}

/// Engine construction parameters (filled in by the service builder).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Execution substrate for every worker.
    pub backend: BackendKind,
    /// AOT artifacts directory (PJRT backend only).
    pub artifacts_dir: PathBuf,
    /// Fabric workers (overlay pipeline replicas at the serving level).
    pub workers: usize,
    /// Maximum batch a worker takes per dispatch.
    pub max_batch: usize,
    /// Per-kernel queue bound (admission control).
    pub queue_depth: usize,
    /// Pipeline replicas inside each sim-backend overlay (Fig. 4).
    pub sim_replicas: usize,
    /// FIFO capacity of each simulated pipeline.
    pub sim_fifo_capacity: usize,
    /// Pre-compiled kernels, shared by every worker.
    pub registry: Arc<KernelRegistry>,
}

/// The serving engine: worker threads + shared queues behind
/// [`crate::service::OverlayService`].
pub struct Engine {
    shared: Arc<Shared>,
    /// Join handles live behind a mutex so [`Engine::shutdown`] can
    /// take `&self` — which is what lets the service layer shut down
    /// through a shared reference (e.g. an `Arc<OverlayService>` held
    /// by a running wire server).
    workers: Mutex<Vec<thread::JoinHandle<Result<()>>>>,
    registry: Arc<KernelRegistry>,
    backend: BackendKind,
    n_workers: usize,
    queue_depth: usize,
    started: Instant,
}

impl Engine {
    /// Start workers over an already-compiled registry.
    pub fn start(cfg: EngineConfig) -> Result<Engine> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(cfg.max_batch >= 1, "need a positive max batch");
        anyhow::ensure!(cfg.queue_depth >= 1, "need a positive queue depth");
        // Fail fast when an artifact-backed substrate cannot possibly
        // start (workers would all error after an expensive spawn).
        if cfg.backend.needs_artifacts() {
            anyhow::ensure!(
                cfg.artifacts_dir.join("manifest.json").exists(),
                "artifacts not found in '{}' — run `make artifacts`",
                cfg.artifacts_dir.display()
            );
        }
        let registry = Arc::clone(&cfg.registry);
        let shared = Arc::new(Shared {
            queues: Mutex::new(QueueState {
                qs: QueueSet::new(registry.len(), cfg.queue_depth),
                shutdown: false,
            }),
            cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("fabric-{wid}"))
                    .spawn(move || worker_loop(wid, cfg, shared, ready))?,
            );
        }
        drop(ready_tx);
        // Wait until every worker has built its backend so request
        // latency measures serving, not startup.
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")?
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(Engine {
            shared,
            workers: Mutex::new(workers),
            registry,
            backend: cfg.backend,
            n_workers: cfg.workers,
            queue_depth: cfg.queue_depth,
            started: Instant::now(),
        })
    }

    /// The submit-port state (what `KernelHandle`s hold).
    pub fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }

    /// The shared compiled-kernel registry.
    pub fn registry(&self) -> &Arc<KernelRegistry> {
        &self.registry
    }

    /// The execution substrate this engine serves through.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Fabric workers serving this engine.
    pub fn workers(&self) -> usize {
        self.n_workers
    }

    /// Per-kernel admission bound.
    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Run `f` over the raw metrics under the lock, with `wall`
    /// refreshed. The service layer uses this to build its typed
    /// snapshot without the engine depending on the service types.
    pub fn with_metrics<R>(&self, f: impl FnOnce(&mut Metrics) -> R) -> R {
        let mut m = self.shared.metrics.lock().unwrap();
        m.wall = self.started.elapsed();
        f(&mut m)
    }

    pub fn completed(&self) -> u64 {
        self.shared.metrics.lock().unwrap().completed
    }

    /// Stop admitting, drain every queue, stop workers. Admitted
    /// requests are completed (replied to) before workers exit.
    /// Takes `&self` and is idempotent: the first caller joins the
    /// workers; later calls find nothing left to join and return.
    pub fn shutdown(&self) -> Result<()> {
        {
            let mut st = self.shared.queues.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let workers = std::mem::take(&mut *self.workers.lock().unwrap());
        for w in workers {
            w.join()
                .map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

fn worker_loop(
    _wid: usize,
    cfg: EngineConfig,
    shared: Arc<Shared>,
    ready: mpsc::Sender<Result<(), String>>,
) -> Result<()> {
    // Each worker owns its backend (PJRT clients are not Send; sim
    // pipelines are stateful). This mirrors per-pipeline configuration
    // BRAMs in Fig. 4.
    let mut backend = match exec::make_backend(
        cfg.backend,
        &cfg.artifacts_dir,
        cfg.sim_replicas,
        cfg.sim_fifo_capacity,
    ) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e}")));
            return Err(e);
        }
    };
    let registry = cfg.registry;
    let caps = backend.capabilities();
    let max_batch = match caps.max_batch {
        Some(limit) => cfg.max_batch.min(limit),
        None => cfg.max_batch,
    };
    // Batch-affinity hint only; switch *accounting* comes from the
    // backend's report when it models context switches itself.
    let mut context: Option<KernelId> = None;
    // One flat input buffer per worker, reused for every batch — the
    // steady-state dispatch loop allocates nothing per packet.
    let mut inputs = FlatBatch::default();
    loop {
        let batch = {
            let mut st = shared.queues.lock().unwrap();
            loop {
                if let Some(b) = st.qs.take_batch(context, max_batch, Instant::now()) {
                    break Some(b);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        let Some(batch) = batch else { return Ok(()) };
        let Some(kernel) = registry.kernel(batch.kernel).cloned() else {
            // Unreachable via the service layer (ids are interned from
            // this registry); kept as a structured reply so a future
            // ingress path cannot hang callers.
            let err = ExecError::UnknownKernel(batch.kernel.to_string());
            for p in batch.items {
                let _ = p.token.send(Err(err.clone()));
            }
            continue;
        };
        let hint_switched = context != Some(batch.kernel);
        // Simulated fabric execution time for the batch at 300 MHz:
        // pipeline fill (latency) + (n-1) more initiations at II.
        // Guarded: an empty batch is a structured error, not a u64
        // underflow.
        let n = batch.items.len();
        let model_cycles = match exec::fabric_exec_cycles(&kernel, n) {
            Ok(c) => c,
            Err(e) => {
                for p in batch.items {
                    let _ = p.token.send(Err(e.clone()));
                }
                continue;
            }
        };
        // Shape guard (the whole-batch analogue of the old per-packet
        // validate_batch scan): a malformed Pending from a future
        // ingress path must produce a structured reply, not panic the
        // worker on the FlatBatch arity assert. Unreachable via the
        // service layer, which validates arity at the door.
        if let Some(p) = batch.items.iter().find(|p| p.inputs.len() != kernel.n_inputs) {
            let err = ExecError::WrongArity {
                kernel: kernel.name.clone(),
                expected: kernel.n_inputs,
                got: p.inputs.len(),
            };
            for p in batch.items {
                let _ = p.token.send(Err(err.clone()));
            }
            continue;
        }
        inputs.reset(kernel.n_inputs);
        inputs.reserve_rows(n);
        for p in &batch.items {
            inputs.push(&p.inputs);
        }
        let result = backend.execute(&kernel, &inputs);
        let now = Instant::now();
        match result {
            Ok(report) => {
                // Prefer measured fabric cycles (sim backend) over the
                // analytical model.
                let exec_us_sim =
                    report.fabric_cycles.unwrap_or(model_cycles) as f64 / SYSTEM_CLOCK_MHZ;
                // Switch accounting: backends that model switching are
                // authoritative (they know whether the context really
                // changed); otherwise fall back to the worker's hint.
                let (switched, switch_us) = if caps.models_context_switch {
                    (
                        report.switch_cycles > 0,
                        report.switch_cycles as f64 / SYSTEM_CLOCK_MHZ,
                    )
                } else {
                    (
                        hint_switched,
                        if hint_switched {
                            kernel.switch_time_us(SYSTEM_CLOCK_MHZ)
                        } else {
                            0.0
                        },
                    )
                };
                {
                    let mut m = shared.metrics.lock().unwrap();
                    m.record_batch(&kernel.name, n, switched, switch_us, exec_us_sim);
                    for p in &batch.items {
                        let wait = now.duration_since(p.enqueued).as_secs_f64() * 1e6;
                        m.latency_us.push(wait);
                        m.queue_wait_us.push(wait - exec_us_sim.min(wait));
                    }
                }
                for (i, p) in batch.items.into_iter().enumerate() {
                    let _ = p.token.send(Ok(report.outputs.row(i).to_vec()));
                }
            }
            Err(e) => {
                // Failed requests land in the `failed` counter only —
                // not `completed`, and not a phantom zero-size batch
                // (which would skew mean_batch_size). No switch is
                // claimed either: the backend may have failed before
                // any context load happened.
                shared.metrics.lock().unwrap().record_failed(n as u64);
                for p in batch.items {
                    let _ = p.token.send(Err(e.clone()));
                }
            }
        }
        context = Some(batch.kernel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(backend: BackendKind, workers: usize, max_batch: usize) -> Engine {
        let registry = Arc::new(KernelRegistry::compile_bench_suite().unwrap());
        Engine::start(EngineConfig {
            backend,
            artifacts_dir: PathBuf::from("artifacts"),
            workers,
            max_batch,
            queue_depth: 1024,
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            registry,
        })
        .unwrap()
    }

    #[test]
    fn engine_serves_by_id_and_drains_on_shutdown() {
        let eng = engine(BackendKind::Sim, 2, 8);
        let id = eng.registry().id_of("gradient").unwrap();
        let mut rxs = Vec::new();
        for i in 0..20 {
            rxs.push(eng.shared().submit(id, vec![3, 5, 2, 7, i]).unwrap());
        }
        // Drain semantics: shutdown must answer everything already
        // admitted even if nothing has been received yet.
        eng.shutdown().unwrap();
        for (i, rx) in rxs.into_iter().enumerate() {
            let out = rx.recv().unwrap().unwrap();
            let i = i as i32;
            assert_eq!(out, vec![1 + 9 + 25 + (2 - i) * (2 - i)]);
        }
    }

    #[test]
    fn shutdown_stops_admission() {
        let eng = engine(BackendKind::Ref, 1, 4);
        let id = eng.registry().id_of("gradient").unwrap();
        let shared = Arc::clone(eng.shared());
        assert!(!shared.is_shut_down());
        eng.shutdown().unwrap();
        assert!(shared.is_shut_down());
        assert_eq!(
            shared.submit(id, vec![0; 5]).unwrap_err(),
            SubmitRejection::ShutDown
        );
        let batch = FlatBatch::from_rows(5, &[vec![0; 5]]);
        assert_eq!(
            shared.submit_batch(id, &batch).unwrap_err(),
            SubmitRejection::ShutDown
        );
    }

    #[test]
    fn batch_admission_is_all_or_nothing() {
        let registry = Arc::new(KernelRegistry::compile_bench_suite().unwrap());
        let eng = Engine::start(EngineConfig {
            backend: BackendKind::Ref,
            artifacts_dir: PathBuf::from("artifacts"),
            workers: 1,
            max_batch: 4,
            queue_depth: 2,
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            registry,
        })
        .unwrap();
        let id = eng.registry().id_of("gradient").unwrap();
        // A batch larger than the whole depth can never be admitted —
        // deterministically Full regardless of worker progress.
        let rows: Vec<Vec<i32>> = (0..3).map(|_| vec![0; 5]).collect();
        let batch = FlatBatch::from_rows(5, &rows);
        match eng.shared().submit_batch(id, &batch) {
            Err(SubmitRejection::Full { limit, .. }) => assert_eq!(limit, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        // The rejection was counted and nothing was admitted.
        assert_eq!(eng.with_metrics(|m| m.rejected), 3);
        assert_eq!(eng.completed(), 0);
        eng.shutdown().unwrap();
    }

    #[test]
    fn missing_artifacts_fails_fast() {
        let registry = Arc::new(KernelRegistry::compile_bench_suite().unwrap());
        let r = Engine::start(EngineConfig {
            backend: BackendKind::Pjrt,
            artifacts_dir: PathBuf::from("/definitely/not/here"),
            workers: 1,
            max_batch: 4,
            queue_depth: 16,
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            registry,
        });
        assert!(r.is_err());
    }
}
