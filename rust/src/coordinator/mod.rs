//! L3 serving coordinator (the software analogue of the paper's Fig. 4
//! system: ARM-side runtime managing hardware tasks on replicated
//! overlay pipelines).
//!
//! Architecture (std threads + channels; tokio is unavailable offline):
//!
//! * callers `submit()` requests (kernel name + input packet) and get a
//!   completion channel;
//! * a shared [`queue::QueueSet`] holds per-kernel FIFOs;
//! * each **fabric worker** thread owns a PJRT [`Engine`] (PJRT clients
//!   are not `Send`, so each worker constructs its own — one worker ≙
//!   one overlay pipeline replica);
//! * workers pull context-affine batches, charge the modeled context
//!   switch cost when they change kernels, execute through PJRT, and
//!   reply;
//! * metrics capture wall-clock latency plus the simulated 300 MHz
//!   fabric timeline (II model + context-switch model).

pub mod metrics;
pub mod queue;

use crate::bench_suite;
use crate::resources::SYSTEM_CLOCK_MHZ;
use crate::runtime::Engine;
use crate::sched::{Program, Timing};
use crate::util::prng::Rng;
use anyhow::{Context, Result};
use metrics::Metrics;
use queue::{Pending, QueueSet};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

/// Completion message for one request.
pub type Reply = Result<Vec<i32>, String>;

type Token = mpsc::Sender<Reply>;

struct Shared {
    queues: Mutex<QueueState>,
    cv: Condvar,
    metrics: Mutex<Metrics>,
}

struct QueueState {
    qs: QueueSet<Token>,
    shutdown: bool,
}

/// Per-kernel fabric timing constants (derived once from the schedule).
#[derive(Debug, Clone, Copy)]
struct KernelTiming {
    ii: u32,
    latency: u64,
    ctx_words: usize,
}

/// The coordinator handle.
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<Result<()>>>,
    timings: BTreeMap<String, KernelTiming>,
    started: Instant,
}

impl Coordinator {
    /// Start `n_workers` fabric workers over the artifacts directory.
    pub fn start(artifacts_dir: &str, n_workers: usize, max_batch: usize) -> Result<Coordinator> {
        anyhow::ensure!(n_workers >= 1, "need at least one worker");
        let shared = Arc::new(Shared {
            queues: Mutex::new(QueueState {
                qs: QueueSet::default(),
                shutdown: false,
            }),
            cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
        });
        // Precompute fabric timing per kernel from the schedules.
        let mut timings = BTreeMap::new();
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name)?;
            let p = Program::schedule(&g)?;
            let t = Timing::of(&p);
            let img = p.context_image()?;
            timings.insert(
                name.to_string(),
                KernelTiming {
                    ii: t.ii,
                    latency: t.latency(),
                    ctx_words: img.load_cycles().map_err(|e| anyhow::anyhow!("{e}"))?,
                },
            );
        }
        let dir = PathBuf::from(artifacts_dir);
        // Fail fast if artifacts are missing (workers would all error).
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "artifacts not found in '{artifacts_dir}' — run `make artifacts`"
        );
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::new();
        for wid in 0..n_workers {
            let shared = Arc::clone(&shared);
            let dir = dir.clone();
            let timings = timings.clone();
            let ready = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("fabric-{wid}"))
                    .spawn(move || worker_loop(wid, &dir, shared, timings, max_batch, ready))?,
            );
        }
        drop(ready_tx);
        // Wait until every worker has compiled its engine so request
        // latency measures serving, not startup.
        for _ in 0..n_workers {
            ready_rx
                .recv()
                .context("worker died during startup")?
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(Coordinator {
            shared,
            workers,
            timings,
            started: Instant::now(),
        })
    }

    /// Submit one request; the reply arrives on the returned channel.
    pub fn submit(&self, kernel: &str, inputs: Vec<i32>) -> Result<mpsc::Receiver<Reply>> {
        anyhow::ensure!(
            self.timings.contains_key(kernel),
            "unknown kernel '{kernel}'"
        );
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.queues.lock().unwrap();
            anyhow::ensure!(!st.shutdown, "coordinator shut down");
            st.qs.push(
                kernel,
                Pending {
                    inputs,
                    enqueued: Instant::now(),
                    token: tx,
                },
            );
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Convenience: submit and block for the reply.
    pub fn call(&self, kernel: &str, inputs: Vec<i32>) -> Result<Vec<i32>> {
        let rx = self.submit(kernel, inputs)?;
        rx.recv()
            .context("worker dropped")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Snapshot + render current metrics.
    pub fn metrics_report(&self) -> String {
        let mut m = self.shared.metrics.lock().unwrap();
        m.wall = self.started.elapsed();
        m.render()
    }

    pub fn completed(&self) -> u64 {
        self.shared.metrics.lock().unwrap().completed
    }

    /// Drain queues and stop workers.
    pub fn shutdown(self) -> Result<()> {
        {
            let mut st = self.shared.queues.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers {
            w.join()
                .map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

fn worker_loop(
    _wid: usize,
    dir: &std::path::Path,
    shared: Arc<Shared>,
    timings: BTreeMap<String, KernelTiming>,
    max_batch: usize,
    ready: mpsc::Sender<Result<(), String>>,
) -> Result<()> {
    // Each worker owns its own PJRT engine (compiled per worker; PJRT
    // clients are not Send). This mirrors per-pipeline configuration
    // BRAMs in Fig. 4.
    let engine = match Engine::load(dir) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e}")));
            return Err(e);
        }
    };
    let max_batch = max_batch.min(engine.batch);
    let mut context: Option<String> = None;
    loop {
        let batch = {
            let mut st = shared.queues.lock().unwrap();
            loop {
                if let Some(b) = st.qs.take_batch(context.as_deref(), max_batch, Instant::now()) {
                    break Some(b);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        let Some(batch) = batch else { return Ok(()) };
        let switched = context.as_deref() != Some(batch.kernel.as_str());
        let t = timings[&batch.kernel];
        let switch_us = t.ctx_words as f64 / SYSTEM_CLOCK_MHZ;
        // Simulated fabric execution time for the batch at 300 MHz:
        // pipeline fill (latency) + (n-1) more initiations at II.
        let n = batch.items.len();
        let exec_cycles = t.latency + (n as u64 - 1) * t.ii as u64;
        let exec_us_sim = exec_cycles as f64 / SYSTEM_CLOCK_MHZ;
        // Real execution through PJRT.
        let inputs: Vec<Vec<i32>> = batch.items.iter().map(|p| p.inputs.clone()).collect();
        let result = engine.execute(&batch.kernel, &inputs);
        let now = Instant::now();
        match result {
            Ok(outputs) => {
                {
                    let mut m = shared.metrics.lock().unwrap();
                    m.record_batch(&batch.kernel, n, switched, switch_us, exec_us_sim);
                    for p in &batch.items {
                        let wait = now.duration_since(p.enqueued).as_secs_f64() * 1e6;
                        m.latency_us.push(wait);
                        m.queue_wait_us.push(wait - exec_us_sim.min(wait));
                    }
                }
                for (p, out) in batch.items.into_iter().zip(outputs) {
                    let _ = p.token.send(Ok(out));
                }
            }
            Err(e) => {
                let msg = format!("{e}");
                let mut m = shared.metrics.lock().unwrap();
                m.record_batch(&batch.kernel, 0, switched, switch_us, 0.0);
                drop(m);
                for p in batch.items {
                    let _ = p.token.send(Err(msg.clone()));
                }
            }
        }
        context = Some(batch.kernel);
    }
}

/// `tmfu serve`: drive the coordinator with a mixed-kernel workload and
/// print the metrics (the paper's Fig. 4 usage model).
pub fn serve_demo(
    artifacts: &str,
    pipelines: usize,
    requests: usize,
    batch: usize,
    seed: u64,
) -> Result<()> {
    let names = bench_suite::all_names();
    let coord = Coordinator::start(artifacts, pipelines, batch)?;
    let mut rng = Rng::new(seed);
    println!(
        "serving {requests} requests across {} kernels on {pipelines} pipeline(s), max batch {batch}",
        names.len()
    );
    let mut rxs = Vec::with_capacity(requests);
    let mut expected = Vec::with_capacity(requests);
    for _ in 0..requests {
        let kernel = *rng.choose(&names);
        let g = bench_suite::load(kernel)?;
        let inputs: Vec<i32> = (0..g.inputs().len())
            .map(|_| rng.range_i64(-1000, 1000) as i32)
            .collect();
        expected.push(crate::dfg::eval(&g, &inputs));
        rxs.push(coord.submit(kernel, inputs)?);
    }
    let mut errors = 0usize;
    for (rx, want) in rxs.into_iter().zip(expected) {
        match rx.recv() {
            Ok(Ok(got)) if got == want => {}
            _ => errors += 1,
        }
    }
    println!("{}", coord.metrics_report());
    coord.shutdown()?;
    if errors > 0 {
        anyhow::bail!("{errors} requests returned wrong results");
    }
    println!("all responses verified against the functional oracle");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<String> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| dir.to_string_lossy().into_owned())
    }

    #[test]
    fn serves_mixed_workload_correctly() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let coord = Coordinator::start(&dir, 1, 8).unwrap();
        // Submit a mix across kernels; verify all results.
        let mut rng = Rng::new(5);
        let names = bench_suite::all_names();
        let mut jobs = Vec::new();
        for _ in 0..40 {
            let kernel = *rng.choose(&names);
            let g = bench_suite::load(kernel).unwrap();
            let inputs: Vec<i32> = (0..g.inputs().len())
                .map(|_| rng.range_i64(-500, 500) as i32)
                .collect();
            let want = crate::dfg::eval(&g, &inputs);
            let rx = coord.submit(kernel, inputs).unwrap();
            jobs.push((rx, want));
        }
        for (rx, want) in jobs {
            assert_eq!(rx.recv().unwrap().unwrap(), want);
        }
        assert_eq!(coord.completed(), 40);
        let report = coord.metrics_report();
        assert!(report.contains("context switches"));
        coord.shutdown().unwrap();
    }

    #[test]
    fn call_blocks_for_result() {
        let Some(dir) = artifacts_dir() else { return };
        let coord = Coordinator::start(&dir, 1, 4).unwrap();
        let out = coord.call("gradient", vec![3, 5, 2, 7, 1]).unwrap();
        assert_eq!(out, vec![1 + 9 + 25 + 1]);
        coord.shutdown().unwrap();
    }

    #[test]
    fn rejects_unknown_kernel_and_bad_arity() {
        let Some(dir) = artifacts_dir() else { return };
        let coord = Coordinator::start(&dir, 1, 4).unwrap();
        assert!(coord.submit("nonesuch", vec![1]).is_err());
        // Wrong arity surfaces as an Err reply, not a hang.
        let r = coord.call("gradient", vec![1, 2]);
        assert!(r.is_err());
        coord.shutdown().unwrap();
    }

    #[test]
    fn missing_artifacts_fails_fast() {
        assert!(Coordinator::start("/definitely/not/here", 1, 4).is_err());
    }
}
