//! L3 serving engine (the software analogue of the paper's Fig. 4
//! system: ARM-side runtime managing hardware tasks on replicated
//! overlay pipelines).
//!
//! This module is **crate-private**: the public client surface is
//! [`crate::service`] (`OverlayService` / `KernelHandle`), which owns
//! an [`Engine`] and talks to it through the typed submit ports below.
//! Nothing outside the crate constructs an engine or pushes a request
//! directly.
//!
//! Architecture (std threads + a shared completion slab; tokio is
//! unavailable offline):
//!
//! * the service layer submits requests through [`Shared::submit`] /
//!   [`Shared::submit_batch`] as (dense [`KernelId`](exec::KernelId),
//!   input row) pairs — names were interned once when the client's
//!   `KernelHandle` was created, so nothing here allocates or compares
//!   strings;
//! * every in-flight request lives in the
//!   [`completion::CompletionSlab`] (DESIGN.md §10): `submit` reserves
//!   a recycled slot (O(1), zero heap allocations in steady state),
//!   workers write replies into the slot in place, and callers block
//!   on a per-shard condvar — no `mpsc::channel` per call, no boxed
//!   reply `Vec`, no waiter thread anywhere;
//! * a shared [`queue::QueueSet`] holds **bounded** per-kernel FIFOs
//!   indexed by kernel id; entries are thin
//!   [`RowSpan`](completion::RowSpan)s into the slab — a whole batch
//!   submit is **one** queue entry regardless of row count, and the
//!   queue splits an oversized span at the worker's row budget so one
//!   big batch fans out across every idle worker and recombines in
//!   its slot by row index. A full queue refuses the request at the
//!   door ([`SubmitRejection::Full`]) — backpressure is explicit, not
//!   implicit queue growth;
//! * each **fabric worker** thread owns a `Box<dyn Backend>` — the
//!   interpreter, the tape-compiled turbo executor, the cycle-accurate
//!   overlay simulator, or the PJRT engine ([`crate::exec`]); backends
//!   are built inside the worker thread because the PJRT client is not
//!   `Send` (one worker ≙ one overlay pipeline replica);
//! * kernels are compiled **once** into a shared
//!   [`Arc<KernelRegistry>`](exec::KernelRegistry) owned by the
//!   service builder — schedule, timing, context image and op tape are
//!   never recomputed per worker;
//! * workers pull context-affine batches into **reused buffers**
//!   ([`QueueSet::take_batch_into`](queue::QueueSet::take_batch_into)
//!   for the spans, a [`FlatBatch`](exec::FlatBatch) for the input
//!   rows, one [`ExecReport`](exec::ExecReport) for the outputs) and
//!   move rows in bulk (`gather_spans` / `complete_spans_ok`: one
//!   shard-lock round-trip per same-shard run instead of two per
//!   row). The steady-state dispatch loop performs **zero heap
//!   allocations** end to end — audited per batch by a thread-local
//!   allocation counter published through
//!   [`Metrics::record_worker_allocs`](metrics::Metrics::record_worker_allocs)
//!   and hard-asserted in the bench;
//! * [`Engine::shutdown`] **drains**: the flag stops admission, but
//!   workers keep taking batches until every queue is empty before
//!   exiting, so every admitted request gets its reply;
//! * metrics capture wall-clock latency plus the simulated 300 MHz
//!   fabric timeline (II model + context-switch model; the sim backend
//!   reports *measured* fabric cycles instead of the model). Counters
//!   are atomics; the sample buffers take one lock per batch.

pub(crate) mod completion;
pub(crate) mod metrics;
pub(crate) mod queue;

use crate::exec::{self, BackendKind, ExecError, ExecReport, FlatBatch, KernelId, KernelRegistry};
use crate::resources::SYSTEM_CLOCK_MHZ;
use crate::util::bench::thread_alloc_count;
use crate::util::sync::LockExt;
use anyhow::{Context, Result};
use completion::{CompletionSlab, RowSpan, Ticket, WakeTarget};
use metrics::{BatchTiming, Metrics, RawMetrics};
use queue::{Queued, QueueSet};
pub(crate) use queue::TenantId;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Why a submit was refused at the door (before any queueing). A
/// `Full` rejection reports whichever bound tripped — the submitting
/// tenant's quota or the kernel's global depth; the service layer
/// attributes the tenant (its `KernelHandle` knows which lane it
/// submitted on).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum SubmitRejection {
    /// The engine is shut down (or draining) — no new admissions.
    ShutDown,
    /// The tenant's quota or the kernel's queue is at its limit.
    Full { queued: usize, limit: usize },
    /// The request carried a deadline budget the queue can no longer
    /// honor: estimated wait (per-kernel service-rate EWMA × queued
    /// rows ÷ workers) already exceeds the remaining budget, so the
    /// request is shed at the door instead of queueing doomed work.
    /// The service layer reports this as `DeadlineExceeded`.
    Infeasible,
}

/// One tenant's admission policy, index-aligned with the dense
/// [`TenantId`] table (entry 0 is the default tenant). Filled in by
/// the service builder from `tenant_weight` / `tenant_quota` knobs or
/// a `tmfu listen --tenants` file.
#[derive(Debug, Clone)]
pub(crate) struct TenantSpec {
    pub(crate) name: String,
    /// DRR scheduling weight (≥ 1): rows served per round relative to
    /// other saturated tenants.
    pub(crate) weight: u32,
    /// Admission quota in rows across every kernel (≥ 1).
    pub(crate) quota: usize,
}

impl TenantSpec {
    /// The catch-all lane: weight 1, quota unbounded (only the global
    /// per-kernel depth binds) — single-tenant engines behave exactly
    /// as before tenancy existed.
    pub(crate) fn default_tenant() -> TenantSpec {
        TenantSpec {
            name: "default".to_string(),
            weight: 1,
            quota: usize::MAX,
        }
    }
}

/// State shared between the submit ports, the workers and the engine
/// handle. The service layer's `KernelHandle`s hold an `Arc<Shared>`,
/// which is what makes them `Clone + Send` sessions independent of the
/// `OverlayService` value itself.
///
/// Lock order: `queues` → slab shard → nothing (doorbells ring after
/// the shard lock is released).
pub(crate) struct Shared {
    queues: Mutex<QueueState>,
    cv: Condvar,
    /// The one completion structure every in-flight request shares.
    pub(crate) slab: CompletionSlab,
    pub(crate) metrics: Metrics,
    /// Worker count, for the admission feasibility estimate (queued
    /// work drains `workers`-wide).
    workers: usize,
}

struct QueueState {
    qs: QueueSet<RowSpan>,
    shutdown: bool,
}

impl Shared {
    /// Submit one pre-validated request (shape checks happen in the
    /// service layer, which owns the kernel's arity — `n_outputs` is
    /// that kernel's output arity and shapes the reply slot). Returns
    /// the slab ticket the reply arrives under. Allocation-free in
    /// steady state: the slot, its buffers, and the queue entry all
    /// recycle. Admission checks the tenant's quota first, then the
    /// kernel's global depth — the rejection reports whichever bound
    /// tripped.
    pub(crate) fn submit(
        &self,
        tenant: TenantId,
        id: KernelId,
        inputs: &[i32],
        n_outputs: usize,
        deadline: Option<Instant>,
        waker: Option<WakeTarget>,
    ) -> Result<Ticket, SubmitRejection> {
        let mut st = self.queues.lock_unpoisoned();
        if st.shutdown {
            return Err(SubmitRejection::ShutDown);
        }
        if let Err((queued, limit)) = admit(&st.qs, tenant, id, 1) {
            drop(st);
            self.metrics.record_rejected(tenant, 1);
            return Err(SubmitRejection::Full { queued, limit });
        }
        if self.deadline_infeasible(&st.qs, id, deadline) {
            drop(st);
            self.metrics.record_shed(tenant, 1);
            return Err(SubmitRejection::Infeasible);
        }
        let ticket = self.slab.reserve(inputs, n_outputs, waker);
        let entry = Queued {
            enqueued: Instant::now(),
            deadline,
            token: RowSpan {
                ticket,
                row: 0,
                len: 1,
            },
        };
        if st.qs.try_push_for(tenant, id, entry).is_err() {
            unreachable!("admission capacity checked above");
        }
        drop(st);
        self.metrics.record_admitted(tenant, 1);
        self.cv.notify_one();
        Ok(ticket)
    }

    /// Submit a whole kernel-affine batch atomically: either every row
    /// is admitted or none is — a half-admitted batch would make
    /// `call_batch` semantics unobservable under backpressure. The
    /// whole batch costs **one** slab reservation (one ticket, one
    /// in-place reply buffer) and **one** queue entry — a single
    /// [`RowSpan`] covering every row, which workers peel apart at
    /// their row budget ([`QueueSet::take_batch_into`]).
    pub(crate) fn submit_batch(
        &self,
        tenant: TenantId,
        id: KernelId,
        batch: &FlatBatch,
        n_outputs: usize,
        deadline: Option<Instant>,
        waker: Option<WakeTarget>,
    ) -> Result<Ticket, SubmitRejection> {
        let n = batch.n_rows();
        let mut st = self.queues.lock_unpoisoned();
        if st.shutdown {
            return Err(SubmitRejection::ShutDown);
        }
        if let Err((queued, limit)) = admit(&st.qs, tenant, id, n) {
            drop(st);
            self.metrics.record_rejected(tenant, n as u64);
            return Err(SubmitRejection::Full { queued, limit });
        }
        if self.deadline_infeasible(&st.qs, id, deadline) {
            drop(st);
            self.metrics.record_shed(tenant, n as u64);
            return Err(SubmitRejection::Infeasible);
        }
        let ticket = self.slab.reserve_batch(batch, n_outputs, waker);
        // A zero-row batch is born Ready in the slab and never queues
        // (the service layer refuses empty batches before this point).
        if n > 0 {
            let entry = Queued {
                enqueued: Instant::now(),
                deadline,
                token: RowSpan {
                    ticket,
                    row: 0,
                    len: n as u32,
                },
            };
            if st.qs.try_push_for(tenant, id, entry).is_err() {
                unreachable!("batch admission capacity checked above");
            }
        }
        drop(st);
        self.metrics.record_admitted(tenant, n as u64);
        self.cv.notify_all();
        Ok(ticket)
    }

    /// Whether `deadline` is already hopeless given the current queue
    /// for `id` (see [`SubmitRejection::Infeasible`]). Conservative on
    /// cold start: with no service-rate sample yet the check always
    /// passes — lazy expiry at take time is the backstop.
    fn deadline_infeasible(
        &self,
        qs: &QueueSet<RowSpan>,
        id: KernelId,
        deadline: Option<Instant>,
    ) -> bool {
        let Some(d) = deadline else { return false };
        let rate = self.metrics.service_rate_us(id);
        if rate <= 0.0 {
            return false;
        }
        let budget = d.saturating_duration_since(Instant::now());
        infeasible(qs.queued_for(id), rate, self.workers, budget)
    }

    /// Cancel every still-queued row of `ticket` and release (or mark
    /// abandoned) its completion slot. Rows a worker already took keep
    /// executing and settle as `completed` into the abandoned slot —
    /// only the purged rows move to the `cancelled` ledger term, which
    /// is what keeps `admitted == completed + failed + cancelled`
    /// exact. Returns the number of rows removed from the queue.
    /// Idempotent: a stale ticket (already settled and collected, or
    /// already cancelled) is a no-op.
    pub(crate) fn cancel(&self, tenant: TenantId, ticket: Ticket) -> usize {
        let removed = {
            let mut st = self.queues.lock_unpoisoned();
            st.qs.purge(|span| span.ticket == ticket)
        };
        // cast-ok: `removed` is bounded by the per-kernel queue depth,
        // far below u32::MAX.
        let live = self.slab.cancel(ticket, removed as u32);
        if live && removed > 0 {
            self.metrics.record_cancelled(tenant, removed as u64);
        }
        removed
    }

    /// Whether the engine has stopped admitting requests.
    pub(crate) fn is_shut_down(&self) -> bool {
        self.queues.lock_unpoisoned().shutdown
    }
}

/// The admission feasibility estimate, pure for unit testing: can a
/// request whose remaining budget is `budget` plausibly clear
/// `queued_rows` rows of backlog when each row costs `rate_us_per_row`
/// µs of wall time and the backlog drains `workers`-wide? Estimates
/// optimistically (perfect worker parallelism, no switch cost) so a
/// shed only fires when the budget is hopeless even under the rosiest
/// model — a false shed is worse than a late expiry.
fn infeasible(queued_rows: usize, rate_us_per_row: f64, workers: usize, budget: Duration) -> bool {
    let est_wait_us = queued_rows as f64 * rate_us_per_row / workers.max(1) as f64;
    est_wait_us > budget.as_secs_f64() * 1e6
}

/// Check both admission bounds for `n` rows without mutating anything:
/// the tenant's quota first (its private share), then the kernel's
/// global depth. Returns the `(queued, limit)` pair of whichever bound
/// tripped, so the typed rejection reports the number the caller can
/// act on.
fn admit(
    qs: &QueueSet<RowSpan>,
    tenant: TenantId,
    id: KernelId,
    n: usize,
) -> Result<(), (usize, usize)> {
    let tenant_queued = qs.tenant_queued(tenant);
    let quota = qs.tenant_quota(tenant);
    if tenant_queued.saturating_add(n) > quota {
        return Err((tenant_queued, quota));
    }
    let queued = qs.queued_for(id);
    let depth = qs.depth();
    if queued + n > depth {
        return Err((queued, depth));
    }
    Ok(())
}

/// Engine construction parameters (filled in by the service builder).
#[derive(Debug, Clone)]
pub(crate) struct EngineConfig {
    /// Execution substrate for every worker.
    pub(crate) backend: BackendKind,
    /// AOT artifacts directory (PJRT backend only).
    pub(crate) artifacts_dir: PathBuf,
    /// Fabric workers (overlay pipeline replicas at the serving level).
    pub(crate) workers: usize,
    /// Maximum batch a worker takes per dispatch.
    pub(crate) max_batch: usize,
    /// Per-kernel queue bound (admission control, global across
    /// tenants).
    pub(crate) queue_depth: usize,
    /// Tenant table, index-aligned with [`TenantId`]; entry 0 is the
    /// default (anonymous) tenant. Never empty.
    pub(crate) tenants: Vec<TenantSpec>,
    /// Pipeline replicas inside each sim-backend overlay (Fig. 4).
    pub(crate) sim_replicas: usize,
    /// FIFO capacity of each simulated pipeline.
    pub(crate) sim_fifo_capacity: usize,
    /// Completion-slot buffer watermark (in `i32` words): recycled
    /// slots shrink burst-sized buffers back toward this, so one giant
    /// batch does not pin its peak allocation on the pool forever.
    pub(crate) slab_trim_words: usize,
    /// Pre-compiled kernels, shared by every worker.
    pub(crate) registry: Arc<KernelRegistry>,
}

/// The serving engine: worker threads + shared queues + the completion
/// slab behind [`crate::service::OverlayService`].
pub(crate) struct Engine {
    shared: Arc<Shared>,
    /// Join handles live behind a mutex so [`Engine::shutdown`] can
    /// take `&self` — which is what lets the service layer shut down
    /// through a shared reference (e.g. an `Arc<OverlayService>` held
    /// by a running wire server).
    workers: Mutex<Vec<thread::JoinHandle<Result<()>>>>,
    registry: Arc<KernelRegistry>,
    backend: BackendKind,
    n_workers: usize,
    queue_depth: usize,
    started: Instant,
}

impl Engine {
    /// Start workers over an already-compiled registry.
    pub(crate) fn start(cfg: EngineConfig) -> Result<Engine> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(cfg.max_batch >= 1, "need a positive max batch");
        anyhow::ensure!(cfg.queue_depth >= 1, "need a positive queue depth");
        anyhow::ensure!(
            !cfg.tenants.is_empty(),
            "need at least the default tenant"
        );
        // Fail fast when an artifact-backed substrate cannot possibly
        // start (workers would all error after an expensive spawn).
        if cfg.backend.needs_artifacts() {
            anyhow::ensure!(
                cfg.artifacts_dir.join("manifest.json").exists(),
                "artifacts not found in '{}' — run `make artifacts`",
                cfg.artifacts_dir.display()
            );
        }
        let registry = Arc::clone(&cfg.registry);
        let lanes: Vec<(u32, usize)> = cfg.tenants.iter().map(|t| (t.weight, t.quota)).collect();
        let shared = Arc::new(Shared {
            queues: Mutex::new(QueueState {
                qs: QueueSet::with_tenants(registry.len(), cfg.queue_depth, &lanes),
                shutdown: false,
            }),
            cv: Condvar::new(),
            // Sharding spreads submit-side lock traffic; a couple of
            // shards per worker is plenty (contention is per shard).
            slab: CompletionSlab::with_trim(
                (cfg.workers * 2).clamp(4, 64),
                cfg.slab_trim_words,
            ),
            metrics: Metrics::new(registry.len(), cfg.tenants.len()),
            workers: cfg.workers,
        });
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let cfg = cfg.clone();
            let ready = ready_tx.clone();
            workers.push(
                thread::Builder::new()
                    .name(format!("fabric-{wid}"))
                    .spawn(move || worker_loop(wid, cfg, shared, ready))?,
            );
        }
        drop(ready_tx);
        // Wait until every worker has built its backend so request
        // latency measures serving, not startup.
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")?
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(Engine {
            shared,
            workers: Mutex::new(workers),
            registry,
            backend: cfg.backend,
            n_workers: cfg.workers,
            queue_depth: cfg.queue_depth,
            started: Instant::now(),
        })
    }

    /// The submit-port state (what `KernelHandle`s hold).
    pub(crate) fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }

    /// The shared compiled-kernel registry.
    pub(crate) fn registry(&self) -> &Arc<KernelRegistry> {
        &self.registry
    }

    /// The execution substrate this engine serves through.
    pub(crate) fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Fabric workers serving this engine.
    pub(crate) fn workers(&self) -> usize {
        self.n_workers
    }

    /// Per-kernel admission bound.
    pub(crate) fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// Copy the raw counters out (sample buffers cloned under a short
    /// lock; percentile sorting happens on the returned value, outside
    /// every engine lock). The service layer builds its typed snapshot
    /// from this.
    pub(crate) fn raw_metrics(&self) -> RawMetrics {
        let mut raw = self.shared.metrics.raw_snapshot();
        raw.wall = self.started.elapsed();
        raw
    }

    /// Requests completed so far (lock-free).
    pub(crate) fn completed(&self) -> u64 {
        self.shared.metrics.completed()
    }

    /// Stop admitting, drain every queue, stop workers. Admitted
    /// requests are completed (replied to) before workers exit.
    /// Takes `&self` and is idempotent: the first caller joins the
    /// workers; later calls find nothing left to join and return.
    pub(crate) fn shutdown(&self) -> Result<()> {
        {
            let mut st = self.shared.queues.lock_unpoisoned();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let workers = std::mem::take(&mut *self.workers.lock_unpoisoned());
        let mut result = Ok(());
        for w in workers {
            let joined = w
                .join()
                .map_err(|_| anyhow::anyhow!("worker panicked"))
                .and_then(|r| r);
            if let Err(e) = joined {
                result = Err(e);
            }
        }
        // The workers are gone. Drain semantics mean every admitted
        // request was completed — but if a worker died mid-batch, its
        // slots can never complete normally. Fail them typed so no
        // waiter blocks forever (a no-op in every healthy shutdown).
        self.shared.slab.fail_all_pending(&ExecError::Backend {
            backend: "engine",
            message: "worker lost before completing the request".to_string(),
        });
        result
    }
}

fn worker_loop(
    _wid: usize,
    cfg: EngineConfig,
    shared: Arc<Shared>,
    ready: mpsc::Sender<Result<(), String>>,
) -> Result<()> {
    // Each worker owns its backend (PJRT clients are not Send; sim
    // pipelines are stateful). This mirrors per-pipeline configuration
    // BRAMs in Fig. 4.
    let mut backend = match exec::make_backend(
        cfg.backend,
        &cfg.artifacts_dir,
        cfg.sim_replicas,
        cfg.sim_fifo_capacity,
    ) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e}")));
            return Err(e);
        }
    };
    let registry = cfg.registry;
    let caps = backend.capabilities();
    let max_batch = match caps.max_batch {
        Some(limit) => cfg.max_batch.min(limit),
        None => cfg.max_batch,
    };
    // Batch-affinity hint only; switch *accounting* comes from the
    // backend's report when it models context switches itself.
    let mut context: Option<KernelId> = None;
    // Reused per-worker buffers: the span batch, the flat input rows,
    // the execution report the backend writes into, and the bulk-op
    // scratch vectors. The steady-state dispatch loop allocates
    // nothing per batch — audited below with a thread-local
    // allocation counter and published through the metrics.
    let mut items: Vec<Queued<RowSpan>> = Vec::new();
    let mut expired: Vec<Queued<RowSpan>> = Vec::new();
    let mut spans: Vec<RowSpan> = Vec::new();
    let mut bad: Vec<RowSpan> = Vec::new();
    let mut inputs = FlatBatch::default();
    let mut report = ExecReport::default();
    loop {
        let taken = {
            let mut st = shared.queues.lock_unpoisoned();
            loop {
                if let Some(k) = st.qs.take_batch_into(
                    context,
                    max_batch,
                    Instant::now(),
                    &mut items,
                    &mut expired,
                ) {
                    break Some(k);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        let Some((batch_kernel, batch_tenant)) = taken else {
            return Ok(());
        };
        // Lazy expiry (before the zero-alloc bracket opens — the typed
        // error below allocates, and expiry is an exceptional path):
        // rows whose deadline lapsed while queued are failed
        // `DeadlineExceeded` right here and **never reach the
        // backend** — the overload acceptance test pins that via
        // backend execute counters. They land in `failed` (plus the
        // `expired_in_queue` cause counter), keeping the ledger exact.
        if !expired.is_empty() {
            let kernel_name = registry
                .kernel(batch_kernel)
                .map_or("?", |k| k.name.as_str());
            let err = ExecError::DeadlineExceeded {
                kernel: kernel_name.to_string(),
            };
            spans.clear();
            spans.extend(expired.iter().map(|it| it.token));
            let rows: u64 = spans.iter().map(|s| s.len as u64).sum();
            shared.metrics.record_failed(batch_tenant, rows);
            shared.metrics.record_expired(batch_tenant, rows);
            shared.slab.complete_spans_err(&spans, &err);
            expired.clear();
            if items.is_empty() {
                // The whole take had expired — nothing to execute, and
                // `fabric_exec_cycles` refuses empty batches anyway.
                continue;
            }
        }
        // Zero-allocation audit, bracket 1 of 2: take → metrics
        // record. (`record_batch` itself is excluded — its sample
        // buffers are unbounded by design; everything else on the
        // dispatch path must stay heap-free.)
        let allocs_at_take = thread_alloc_count();
        spans.clear();
        spans.extend(items.iter().map(|it| it.token));
        let Some(kernel) = registry.kernel(batch_kernel) else {
            // Unreachable via the service layer (ids are interned from
            // this registry); kept as a structured reply so a future
            // ingress path cannot hang callers.
            let err = ExecError::UnknownKernel(batch_kernel.to_string());
            shared
                .metrics
                .record_failed(batch_tenant, spans.iter().map(|s| s.len as u64).sum());
            shared.slab.complete_spans_err(&spans, &err);
            items.clear();
            continue;
        };
        let hint_switched = context != Some(batch_kernel);
        // Gather the input rows out of the slab into the reused flat
        // buffer — one shard-lock round-trip per same-shard span run.
        // A malformed slot (wrong arity, from a future ingress path —
        // the service layer validates at the door) comes back in
        // `bad`: those spans get a structured reply and the batch
        // shrinks to the survivors instead of panicking the worker.
        inputs.reset(kernel.n_inputs);
        bad.clear();
        shared.slab.gather_spans(&spans, &mut inputs, &mut bad);
        if !bad.is_empty() {
            let err = ExecError::WrongArity {
                kernel: kernel.name.clone(),
                expected: kernel.n_inputs,
                got: 0,
            };
            shared
                .metrics
                .record_failed(batch_tenant, bad.iter().map(|s| s.len as u64).sum());
            shared.slab.complete_spans_err(&bad, &err);
            items.retain(|it| !bad.contains(&it.token));
            spans.retain(|s| !bad.contains(s));
            if spans.is_empty() {
                items.clear();
                continue;
            }
        }
        let n = inputs.n_rows();
        // Simulated fabric execution time for the batch at 300 MHz:
        // pipeline fill (latency) + (n-1) more initiations at II.
        // Guarded: an empty batch is a structured error, not a u64
        // underflow (unreachable here — every queued span has rows).
        let model_cycles = match exec::fabric_exec_cycles(kernel, n) {
            Ok(c) => c,
            Err(e) => {
                shared.metrics.record_failed(batch_tenant, n as u64);
                shared.slab.complete_spans_err(&spans, &e);
                items.clear();
                continue;
            }
        };
        // Execute + reply under an unwind guard: a panicking backend
        // must not strand this batch's slots in Pending — the old
        // per-call channels failed waiters for free when a panicking
        // worker dropped its senders, and the slab keeps that
        // containment explicitly. `replied` tracks whether the spans
        // got their answer, so the handler fails exactly the ones
        // still pending, then the panic is re-raised (the thread
        // still dies; the next `shutdown` reports it, as before).
        let mut replied = false;
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let exec_started = Instant::now();
            let result = backend.execute_into(kernel, &inputs, &mut report);
            let now = Instant::now();
            match result {
                Ok(()) => {
                    // Shape-check the backend's report before touching
                    // metrics or slots (the reply-side twin of the
                    // input-arity guard above): a short or mis-shaped
                    // output is a structured backend failure — never a
                    // mid-loop panic that would double-count the batch
                    // or poison a shard lock from inside a completion.
                    if report.outputs.n_rows() != n || report.outputs.arity() != kernel.n_outputs
                    {
                        let e = ExecError::Backend {
                            backend: "engine",
                            message: format!(
                                "backend returned {} rows x {} words for '{}', expected {} x {}",
                                report.outputs.n_rows(),
                                report.outputs.arity(),
                                kernel.name,
                                n,
                                kernel.n_outputs
                            ),
                        };
                        shared.metrics.record_failed(batch_tenant, n as u64);
                        shared.slab.complete_spans_err(&spans, &e);
                        replied = true;
                        return;
                    }
                    // Prefer measured fabric cycles (sim backend) over
                    // the analytical model.
                    let exec_us_sim =
                        report.fabric_cycles.unwrap_or(model_cycles) as f64 / SYSTEM_CLOCK_MHZ;
                    // Switch accounting: backends that model switching
                    // are authoritative (they know whether the context
                    // really changed); otherwise the worker's hint.
                    let (switched, switch_us) = if caps.models_context_switch {
                        (
                            report.switch_cycles > 0,
                            report.switch_cycles as f64 / SYSTEM_CLOCK_MHZ,
                        )
                    } else {
                        (
                            hint_switched,
                            if hint_switched {
                                kernel.switch_time_us(SYSTEM_CLOCK_MHZ)
                            } else {
                                0.0
                            },
                        )
                    };
                    // Bracket 1 closes here; record_batch (unbounded
                    // sample buffers, excluded from the audit) runs
                    // between the brackets. Record first — counters
                    // are visible the moment a waiter wakes.
                    let bracket1 = thread_alloc_count() - allocs_at_take;
                    shared.metrics.record_batch(
                        batch_kernel,
                        batch_tenant,
                        n,
                        BatchTiming {
                            switched,
                            switch_us,
                            exec_us_sim,
                        },
                        items.iter().flat_map(|it| {
                            let wait = now.duration_since(it.enqueued).as_secs_f64() * 1e6;
                            (0..it.token.len).map(move |_| wait)
                        }),
                    );
                    // Feed the admission feasibility estimate one
                    // measured wall-µs-per-row sample (atomic blend,
                    // allocation-free — safe inside the audit window).
                    shared.metrics.record_service_rate(
                        batch_kernel,
                        now.duration_since(exec_started).as_secs_f64() * 1e6 / n as f64,
                    );
                    // Bracket 2: reply writes (bulk, in place).
                    let allocs_at_reply = thread_alloc_count();
                    shared.slab.complete_spans_ok(&spans, &report.outputs);
                    replied = true;
                    let bracket2 = thread_alloc_count() - allocs_at_reply;
                    shared.metrics.record_worker_allocs(bracket1 + bracket2);
                }
                Err(e) => {
                    // Failed requests land in the `failed` counter
                    // only — not `completed`, and not a phantom
                    // zero-size batch (which would skew
                    // mean_batch_size). No switch is claimed either:
                    // the backend may have failed before any context
                    // load happened.
                    shared.metrics.record_failed(batch_tenant, n as u64);
                    shared.slab.complete_spans_err(&spans, &e);
                    replied = true;
                }
            }
        }));
        if let Err(payload) = outcome {
            if !replied {
                let err = ExecError::Backend {
                    backend: "engine",
                    message: "worker panicked while executing the batch".to_string(),
                };
                shared.metrics.record_failed(batch_tenant, n as u64);
                shared.slab.complete_spans_err(&spans, &err);
            }
            std::panic::resume_unwind(payload);
        }
        items.clear();
        context = Some(batch_kernel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine(backend: BackendKind, workers: usize, max_batch: usize) -> Engine {
        let registry = Arc::new(KernelRegistry::compile_bench_suite().unwrap());
        Engine::start(EngineConfig {
            backend,
            artifacts_dir: PathBuf::from("artifacts"),
            workers,
            max_batch,
            queue_depth: 1024,
            tenants: vec![TenantSpec::default_tenant()],
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            slab_trim_words: completion::DEFAULT_TRIM_WORDS,
            registry,
        })
        .unwrap()
    }

    #[test]
    fn engine_serves_by_id_and_drains_on_shutdown() {
        let eng = engine(BackendKind::Sim, 2, 8);
        let id = eng.registry().id_of("gradient").unwrap();
        let mut tickets = Vec::new();
        for i in 0..20i32 {
            tickets.push(eng.shared().submit(TenantId::DEFAULT, id, &[3, 5, 2, 7, i], 1, None, None).unwrap());
        }
        // Drain semantics: shutdown must answer everything already
        // admitted even if nothing has been collected yet.
        eng.shutdown().unwrap();
        let mut out = Vec::new();
        for (i, t) in tickets.into_iter().enumerate() {
            let slab = &eng.shared().slab;
            slab.wait_row(t, None, &mut out)
                .expect("no deadline")
                .unwrap();
            let i = i as i32;
            assert_eq!(out, vec![1 + 9 + 25 + (2 - i) * (2 - i)]);
        }
        // Every slot was collected: the slab is fully recycled.
        assert_eq!(eng.shared().slab.live_slots(), 0);
    }

    #[test]
    fn oversized_batch_splits_across_workers_and_recombines() {
        // 131 rows (deliberately not a multiple of the 8-row budget)
        // through 4 workers taking at most 8 rows each: the one queued
        // span is peeled apart by whichever workers are idle and the
        // pieces recombine in the slot by row index, in order.
        let eng = engine(BackendKind::Turbo, 4, 8);
        let id = eng.registry().id_of("gradient").unwrap();
        let rows: Vec<Vec<i32>> = (0..131i32).map(|i| vec![3, 5, 2, 7, i]).collect();
        let batch = FlatBatch::from_rows(5, &rows);
        let t = eng.shared().submit_batch(TenantId::DEFAULT, id, &batch, 1, None, None).unwrap();
        let mut out = FlatBatch::default();
        eng.shared()
            .slab
            .wait_batch(t, None, &mut out)
            .expect("no deadline")
            .unwrap();
        assert_eq!(out.n_rows(), 131);
        for (i, got) in out.iter().enumerate() {
            let i = i as i32;
            assert_eq!(got, &[1 + 9 + 25 + (2 - i) * (2 - i)], "row {i}");
        }
        let raw = eng.raw_metrics();
        assert_eq!(raw.completed, 131);
        assert_eq!(raw.failed, 0);
        eng.shutdown().unwrap();
        assert_eq!(eng.shared().slab.live_slots(), 0);
    }

    #[test]
    fn shutdown_stops_admission() {
        let eng = engine(BackendKind::Ref, 1, 4);
        let id = eng.registry().id_of("gradient").unwrap();
        let shared = Arc::clone(eng.shared());
        assert!(!shared.is_shut_down());
        eng.shutdown().unwrap();
        assert!(shared.is_shut_down());
        assert_eq!(
            shared.submit(TenantId::DEFAULT, id, &[0; 5], 1, None, None).unwrap_err(),
            SubmitRejection::ShutDown
        );
        let batch = FlatBatch::from_rows(5, &[vec![0; 5]]);
        assert_eq!(
            shared.submit_batch(TenantId::DEFAULT, id, &batch, 1, None, None).unwrap_err(),
            SubmitRejection::ShutDown
        );
    }

    #[test]
    fn batch_admission_is_all_or_nothing() {
        let registry = Arc::new(KernelRegistry::compile_bench_suite().unwrap());
        let eng = Engine::start(EngineConfig {
            backend: BackendKind::Ref,
            artifacts_dir: PathBuf::from("artifacts"),
            workers: 1,
            max_batch: 4,
            queue_depth: 2,
            tenants: vec![TenantSpec::default_tenant()],
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            slab_trim_words: completion::DEFAULT_TRIM_WORDS,
            registry,
        })
        .unwrap();
        let id = eng.registry().id_of("gradient").unwrap();
        // A batch larger than the whole depth can never be admitted —
        // deterministically Full regardless of worker progress.
        let rows: Vec<Vec<i32>> = (0..3).map(|_| vec![0; 5]).collect();
        let batch = FlatBatch::from_rows(5, &rows);
        match eng.shared().submit_batch(TenantId::DEFAULT, id, &batch, 1, None, None) {
            Err(SubmitRejection::Full { limit, .. }) => assert_eq!(limit, 2),
            other => panic!("expected Full, got {other:?}"),
        }
        // The rejection was counted, nothing was admitted, and no
        // slab slot was reserved for the refused batch.
        assert_eq!(eng.raw_metrics().rejected, 3);
        assert_eq!(eng.completed(), 0);
        assert_eq!(eng.shared().slab.live_slots(), 0);
        eng.shutdown().unwrap();
    }

    #[test]
    fn infeasibility_estimate_math() {
        // 1000 queued rows at 100 µs/row over 4 workers ⇒ 25 ms of
        // estimated wait: a 20 ms budget is hopeless, 30 ms is not.
        assert!(infeasible(1000, 100.0, 4, Duration::from_millis(20)));
        assert!(!infeasible(1000, 100.0, 4, Duration::from_millis(30)));
        // An empty queue is always feasible, even with zero budget.
        assert!(!infeasible(0, 100.0, 4, Duration::ZERO));
        // Degenerate worker count clamps to 1 instead of dividing by 0.
        assert!(infeasible(10, 100.0, 0, Duration::from_micros(500)));
    }

    #[test]
    fn expired_rows_fail_typed_without_executing() {
        let eng = engine(BackendKind::Turbo, 1, 8);
        let id = eng.registry().id_of("gradient").unwrap();
        // Deadlines already lapsed at submit time: admission lets them
        // through (no service-rate sample yet, so feasibility is
        // skipped) and lazy expiry evicts them at take time.
        let past = Instant::now();
        let mut tickets = Vec::new();
        for i in 0..8i32 {
            tickets.push(
                eng.shared()
                    .submit(TenantId::DEFAULT, id, &[3, 5, 2, 7, i], 1, Some(past), None)
                    .unwrap(),
            );
        }
        let mut out = Vec::new();
        for t in tickets {
            let err = eng
                .shared()
                .slab
                .wait_row(t, None, &mut out)
                .expect("no wait deadline")
                .unwrap_err();
            assert!(matches!(err, ExecError::DeadlineExceeded { .. }), "{err}");
        }
        eng.shutdown().unwrap();
        let raw = eng.raw_metrics();
        let t0 = &raw.per_tenant[0];
        assert_eq!(t0.admitted, 8);
        assert_eq!(t0.failed, 8);
        assert_eq!(t0.expired_in_queue, 8);
        assert_eq!(t0.admitted, t0.completed + t0.failed + t0.cancelled);
        // Nothing executed: zero batches is the backend-side proof
        // that expired rows never reached it.
        assert_eq!(raw.batches, 0);
        assert_eq!(raw.completed, 0);
        assert_eq!(eng.shared().slab.live_slots(), 0);
    }

    #[test]
    fn cancel_purges_queued_rows_and_frees_the_slot() {
        // Keep the single worker busy on a long batch so follow-up
        // requests reliably sit queued when the cancel lands. If the
        // worker wins the race anyway, the cancel degrades to an
        // abandon (rows settle as completed into a freed slot) — the
        // ledger must balance either way.
        let eng = engine(BackendKind::Sim, 1, 8);
        let id = eng.registry().id_of("gradient").unwrap();
        let rows: Vec<Vec<i32>> = (0..2048i32).map(|i| vec![3, 5, 2, 7, i]).collect();
        let big = FlatBatch::from_rows(5, &rows);
        let big_t = eng
            .shared()
            .submit_batch(TenantId::DEFAULT, id, &big, 1, None, None)
            .unwrap();
        let mut cancelled = 0u64;
        for i in 0..8i32 {
            let t = eng
                .shared()
                .submit(TenantId::DEFAULT, id, &[0, 0, 0, 0, i], 1, None, None)
                .unwrap();
            cancelled += eng.shared().cancel(TenantId::DEFAULT, t) as u64;
        }
        // The cancelled ticket is dead — nobody collects it. The big
        // batch still completes in full.
        let mut out = FlatBatch::default();
        eng.shared()
            .slab
            .wait_batch(big_t, None, &mut out)
            .expect("no wait deadline")
            .unwrap();
        assert_eq!(out.n_rows(), 2048);
        eng.shutdown().unwrap();
        let raw = eng.raw_metrics();
        let t0 = &raw.per_tenant[0];
        assert_eq!(t0.cancelled, cancelled);
        assert_eq!(t0.admitted, 2048 + 8);
        assert_eq!(t0.admitted, t0.completed + t0.failed + t0.cancelled);
        // Occupancy: cancelled slots were freed on the spot, raced
        // ones were freed by their last completion (abandon), and the
        // collected batch recycled normally.
        assert_eq!(eng.shared().slab.live_slots(), 0);
        // A second cancel of an already-dead ticket is a no-op.
        assert!(cancelled > 0, "expected at least one queued cancel");
    }

    #[test]
    fn infeasible_deadline_is_shed_at_the_door() {
        let eng = engine(BackendKind::Sim, 1, 8);
        let id = eng.registry().id_of("gradient").unwrap();
        // Pretend history says each row costs ~1 s of service: any
        // backlog at all makes a 1 ms budget hopeless.
        eng.shared().metrics.record_service_rate(id, 1e6);
        let rows: Vec<Vec<i32>> = (0..4096i32).map(|i| vec![3, 5, 2, 7, i]).collect();
        let big = FlatBatch::from_rows(5, &rows);
        let big_t = eng
            .shared()
            .submit_batch(TenantId::DEFAULT, id, &big, 1, None, None)
            .unwrap();
        let deadline = Instant::now() + Duration::from_millis(1);
        let r = eng
            .shared()
            .submit(TenantId::DEFAULT, id, &[0; 5], 1, Some(deadline), None);
        assert_eq!(r.unwrap_err(), SubmitRejection::Infeasible);
        // A deadline-free request sails past the feasibility check.
        let ok_t = eng
            .shared()
            .submit(TenantId::DEFAULT, id, &[0; 5], 1, None, None)
            .unwrap();
        let mut out = FlatBatch::default();
        eng.shared()
            .slab
            .wait_batch(big_t, None, &mut out)
            .expect("no wait deadline")
            .unwrap();
        let mut row = Vec::new();
        eng.shared()
            .slab
            .wait_row(ok_t, None, &mut row)
            .expect("no wait deadline")
            .unwrap();
        eng.shutdown().unwrap();
        let raw = eng.raw_metrics();
        let t0 = &raw.per_tenant[0];
        assert_eq!(t0.shed_at_admission, 1);
        assert_eq!(raw.shed_at_admission, 1);
        // Shed requests were never admitted: the ledger balances
        // without them, and no slab slot was reserved.
        assert_eq!(t0.admitted, 4096 + 1);
        assert_eq!(t0.admitted, t0.completed + t0.failed + t0.cancelled);
        assert_eq!(eng.shared().slab.live_slots(), 0);
    }

    #[test]
    fn missing_artifacts_fails_fast() {
        let registry = Arc::new(KernelRegistry::compile_bench_suite().unwrap());
        let r = Engine::start(EngineConfig {
            backend: BackendKind::Pjrt,
            artifacts_dir: PathBuf::from("/definitely/not/here"),
            workers: 1,
            max_batch: 4,
            queue_depth: 16,
            tenants: vec![TenantSpec::default_tenant()],
            sim_replicas: 1,
            sim_fifo_capacity: 4096,
            slab_trim_words: completion::DEFAULT_TRIM_WORDS,
            registry,
        });
        assert!(r.is_err());
    }
}
