//! L3 serving coordinator (the software analogue of the paper's Fig. 4
//! system: ARM-side runtime managing hardware tasks on replicated
//! overlay pipelines).
//!
//! Architecture (std threads + channels; tokio is unavailable offline):
//!
//! * callers `submit()` requests (kernel name + input packet) and get a
//!   completion channel; the name is interned to a dense
//!   [`KernelId`](exec::KernelId) at ingress so nothing downstream
//!   allocates or compares strings;
//! * a shared [`queue::QueueSet`] holds per-kernel FIFOs indexed by
//!   kernel id;
//! * each **fabric worker** thread owns a `Box<dyn Backend>` — the
//!   interpreter, the tape-compiled turbo executor, the cycle-accurate
//!   overlay simulator, or the PJRT engine ([`crate::exec`]); backends
//!   are built inside the worker thread because the PJRT client is not
//!   `Send` (one worker ≙ one overlay pipeline replica);
//! * kernels are compiled **once** into a shared
//!   [`Arc<KernelRegistry>`](exec::KernelRegistry) — schedule, timing,
//!   context image and op tape are no longer recomputed per worker;
//! * workers pull context-affine batches into a **reused
//!   [`FlatBatch`](exec::FlatBatch) buffer** — the request side of the
//!   dispatch loop performs no per-packet allocation in steady state
//!   (replies still cost one `Vec` each: the `Reply` channel contract
//!   hands each caller an owned row) — charge the modeled context
//!   switch cost when they change kernels, execute through their
//!   backend, and reply;
//! * metrics capture wall-clock latency plus the simulated 300 MHz
//!   fabric timeline (II model + context-switch model; the sim backend
//!   reports *measured* fabric cycles instead of the model).

pub mod metrics;
pub mod queue;

use crate::bench_suite;
use crate::exec::{self, BackendConfig, BackendKind, FlatBatch, KernelId, KernelRegistry};
use crate::resources::SYSTEM_CLOCK_MHZ;
use crate::util::prng::Rng;
use anyhow::{Context, Result};
use metrics::Metrics;
use queue::{Pending, QueueSet};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Instant;

/// Completion message for one request.
pub type Reply = Result<Vec<i32>, String>;

type Token = mpsc::Sender<Reply>;

struct Shared {
    queues: Mutex<QueueState>,
    cv: Condvar,
    metrics: Mutex<Metrics>,
}

struct QueueState {
    qs: QueueSet<Token>,
    shutdown: bool,
}

/// Coordinator construction parameters.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Execution substrate for every worker.
    pub backend: BackendKind,
    /// AOT artifacts directory (PJRT backend only).
    pub artifacts_dir: String,
    /// Fabric workers (overlay pipeline replicas at the serving level).
    pub workers: usize,
    /// Maximum batch a worker takes per dispatch.
    pub max_batch: usize,
    /// Pipeline replicas inside each sim-backend overlay (Fig. 4).
    pub sim_replicas: usize,
}

impl CoordinatorConfig {
    pub fn new(backend: BackendKind) -> CoordinatorConfig {
        CoordinatorConfig {
            backend,
            artifacts_dir: "artifacts".to_string(),
            workers: 1,
            max_batch: 16,
            sim_replicas: 1,
        }
    }
}

/// The coordinator handle.
pub struct Coordinator {
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<Result<()>>>,
    registry: Arc<KernelRegistry>,
    backend: BackendKind,
    started: Instant,
}

impl Coordinator {
    /// Start a backend-generic coordinator.
    pub fn start_with(cfg: CoordinatorConfig) -> Result<Coordinator> {
        anyhow::ensure!(cfg.workers >= 1, "need at least one worker");
        anyhow::ensure!(cfg.max_batch >= 1, "need a positive max batch");
        // Compile every kernel once; workers share the registry.
        let registry = Arc::new(KernelRegistry::compile_bench_suite()?);
        // Fail fast when an artifact-backed substrate cannot possibly
        // start (workers would all error after an expensive spawn).
        if cfg.backend.needs_artifacts() {
            anyhow::ensure!(
                PathBuf::from(&cfg.artifacts_dir).join("manifest.json").exists(),
                "artifacts not found in '{}' — run `make artifacts`",
                cfg.artifacts_dir
            );
        }
        let shared = Arc::new(Shared {
            queues: Mutex::new(QueueState {
                qs: QueueSet::new(registry.len()),
                shutdown: false,
            }),
            cv: Condvar::new(),
            metrics: Mutex::new(Metrics::default()),
        });
        let mut backend_cfg = BackendConfig::new(cfg.backend);
        backend_cfg.artifacts_dir = PathBuf::from(&cfg.artifacts_dir);
        backend_cfg.sim_replicas = cfg.sim_replicas;
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers {
            let shared = Arc::clone(&shared);
            let registry = Arc::clone(&registry);
            let backend_cfg = backend_cfg.clone();
            let ready = ready_tx.clone();
            let max_batch = cfg.max_batch;
            workers.push(
                thread::Builder::new()
                    .name(format!("fabric-{wid}"))
                    .spawn(move || {
                        worker_loop(wid, backend_cfg, shared, registry, max_batch, ready)
                    })?,
            );
        }
        drop(ready_tx);
        // Wait until every worker has built its backend so request
        // latency measures serving, not startup.
        for _ in 0..cfg.workers {
            ready_rx
                .recv()
                .context("worker died during startup")?
                .map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(Coordinator {
            shared,
            workers,
            registry,
            backend: cfg.backend,
            started: Instant::now(),
        })
    }

    /// Back-compat shorthand: `n_workers` PJRT workers over the
    /// artifacts directory (the pre-backend-layer entry point).
    pub fn start(artifacts_dir: &str, n_workers: usize, max_batch: usize) -> Result<Coordinator> {
        let mut cfg = CoordinatorConfig::new(BackendKind::Pjrt);
        cfg.artifacts_dir = artifacts_dir.to_string();
        cfg.workers = n_workers;
        cfg.max_batch = max_batch;
        Coordinator::start_with(cfg)
    }

    /// The execution substrate this coordinator serves through.
    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// The shared compiled-kernel registry.
    pub fn registry(&self) -> &Arc<KernelRegistry> {
        &self.registry
    }

    /// Submit one request; the reply arrives on the returned channel.
    /// Shape errors (unknown kernel, wrong arity) are rejected here,
    /// before the request can be co-batched with valid ones — a
    /// malformed request must never fail its batch neighbours. The
    /// kernel name is interned here; past this point the request is a
    /// `KernelId` and a flat input row.
    pub fn submit(&self, kernel: &str, inputs: Vec<i32>) -> Result<mpsc::Receiver<Reply>> {
        let Some(id) = self.registry.id_of(kernel) else {
            anyhow::bail!("{}", exec::ExecError::UnknownKernel(kernel.to_string()));
        };
        let k = self.registry.kernel(id).expect("interned id resolves");
        anyhow::ensure!(
            inputs.len() == k.n_inputs,
            "{}",
            exec::ExecError::WrongArity {
                kernel: kernel.to_string(),
                expected: k.n_inputs,
                got: inputs.len(),
            }
        );
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.queues.lock().unwrap();
            anyhow::ensure!(!st.shutdown, "coordinator shut down");
            st.qs.push(
                id,
                Pending {
                    inputs,
                    enqueued: Instant::now(),
                    token: tx,
                },
            );
        }
        self.shared.cv.notify_one();
        Ok(rx)
    }

    /// Convenience: submit and block for the reply.
    pub fn call(&self, kernel: &str, inputs: Vec<i32>) -> Result<Vec<i32>> {
        let rx = self.submit(kernel, inputs)?;
        rx.recv()
            .context("worker dropped")?
            .map_err(|e| anyhow::anyhow!(e))
    }

    /// Snapshot + render current metrics.
    pub fn metrics_report(&self) -> String {
        let mut m = self.shared.metrics.lock().unwrap();
        m.wall = self.started.elapsed();
        m.render()
    }

    pub fn completed(&self) -> u64 {
        self.shared.metrics.lock().unwrap().completed
    }

    /// Drain queues and stop workers.
    pub fn shutdown(self) -> Result<()> {
        {
            let mut st = self.shared.queues.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for w in self.workers {
            w.join()
                .map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

fn worker_loop(
    _wid: usize,
    backend_cfg: BackendConfig,
    shared: Arc<Shared>,
    registry: Arc<KernelRegistry>,
    max_batch: usize,
    ready: mpsc::Sender<Result<(), String>>,
) -> Result<()> {
    // Each worker owns its backend (PJRT clients are not Send; sim
    // pipelines are stateful). This mirrors per-pipeline configuration
    // BRAMs in Fig. 4.
    let mut backend = match exec::make_backend(&backend_cfg) {
        Ok(b) => {
            let _ = ready.send(Ok(()));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(format!("{e}")));
            return Err(e);
        }
    };
    let caps = backend.capabilities();
    let max_batch = match caps.max_batch {
        Some(limit) => max_batch.min(limit),
        None => max_batch,
    };
    // Batch-affinity hint only; switch *accounting* comes from the
    // backend's report when it models context switches itself.
    let mut context: Option<KernelId> = None;
    // One flat input buffer per worker, reused for every batch — the
    // steady-state dispatch loop allocates nothing per packet.
    let mut inputs = FlatBatch::default();
    loop {
        let batch = {
            let mut st = shared.queues.lock().unwrap();
            loop {
                if let Some(b) = st.qs.take_batch(context, max_batch, Instant::now()) {
                    break Some(b);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        let Some(batch) = batch else { return Ok(()) };
        let Some(kernel) = registry.kernel(batch.kernel).cloned() else {
            // Unreachable via submit() (ids are interned from this
            // registry); kept as a structured reply so a future
            // ingress path cannot hang callers.
            let msg = exec::ExecError::UnknownKernel(batch.kernel.to_string()).to_string();
            for p in batch.items {
                let _ = p.token.send(Err(msg.clone()));
            }
            continue;
        };
        let hint_switched = context != Some(batch.kernel);
        // Simulated fabric execution time for the batch at 300 MHz:
        // pipeline fill (latency) + (n-1) more initiations at II.
        // Guarded: an empty batch is a structured error, not a u64
        // underflow.
        let n = batch.items.len();
        let model_cycles = match exec::fabric_exec_cycles(&kernel, n) {
            Ok(c) => c,
            Err(e) => {
                let msg = e.to_string();
                for p in batch.items {
                    let _ = p.token.send(Err(msg.clone()));
                }
                continue;
            }
        };
        // Shape guard (the whole-batch analogue of the old per-packet
        // validate_batch scan): a malformed Pending from a future
        // ingress path must produce a structured reply, not panic the
        // worker on the FlatBatch arity assert. Unreachable via
        // submit(), which validates arity at the door.
        if let Some(p) = batch.items.iter().find(|p| p.inputs.len() != kernel.n_inputs) {
            let msg = exec::ExecError::WrongArity {
                kernel: kernel.name.clone(),
                expected: kernel.n_inputs,
                got: p.inputs.len(),
            }
            .to_string();
            for p in batch.items {
                let _ = p.token.send(Err(msg.clone()));
            }
            continue;
        }
        inputs.reset(kernel.n_inputs);
        inputs.reserve_rows(n);
        for p in &batch.items {
            inputs.push(&p.inputs);
        }
        let result = backend.execute(&kernel, &inputs);
        let now = Instant::now();
        match result {
            Ok(report) => {
                // Prefer measured fabric cycles (sim backend) over the
                // analytical model.
                let exec_us_sim =
                    report.fabric_cycles.unwrap_or(model_cycles) as f64 / SYSTEM_CLOCK_MHZ;
                // Switch accounting: backends that model switching are
                // authoritative (they know whether the context really
                // changed); otherwise fall back to the worker's hint.
                let (switched, switch_us) = if caps.models_context_switch {
                    (
                        report.switch_cycles > 0,
                        report.switch_cycles as f64 / SYSTEM_CLOCK_MHZ,
                    )
                } else {
                    (
                        hint_switched,
                        if hint_switched {
                            kernel.switch_time_us(SYSTEM_CLOCK_MHZ)
                        } else {
                            0.0
                        },
                    )
                };
                {
                    let mut m = shared.metrics.lock().unwrap();
                    m.record_batch(&kernel.name, n, switched, switch_us, exec_us_sim);
                    for p in &batch.items {
                        let wait = now.duration_since(p.enqueued).as_secs_f64() * 1e6;
                        m.latency_us.push(wait);
                        m.queue_wait_us.push(wait - exec_us_sim.min(wait));
                    }
                }
                for (i, p) in batch.items.into_iter().enumerate() {
                    let _ = p.token.send(Ok(report.outputs.row(i).to_vec()));
                }
            }
            Err(e) => {
                // Conservative: claim no switch (the backend may have
                // failed before any context load happened).
                let msg = e.to_string();
                let mut m = shared.metrics.lock().unwrap();
                m.record_batch(&kernel.name, 0, false, 0.0, 0.0);
                drop(m);
                for p in batch.items {
                    let _ = p.token.send(Err(msg.clone()));
                }
            }
        }
        context = Some(batch.kernel);
    }
}

/// `tmfu serve`: drive the coordinator with a mixed-kernel workload and
/// print the metrics (the paper's Fig. 4 usage model). Every response
/// is verified against the functional oracle.
pub fn serve_demo(
    backend: BackendKind,
    artifacts: &str,
    pipelines: usize,
    requests: usize,
    batch: usize,
    seed: u64,
) -> Result<()> {
    let names = bench_suite::all_names();
    let mut cfg = CoordinatorConfig::new(backend);
    cfg.artifacts_dir = artifacts.to_string();
    cfg.workers = pipelines;
    cfg.max_batch = batch;
    let coord = Coordinator::start_with(cfg)?;
    let mut rng = Rng::new(seed);
    println!(
        "serving {requests} requests across {} kernels on {pipelines} pipeline(s), \
         max batch {batch}, backend '{backend}'",
        names.len()
    );
    let mut rxs = Vec::with_capacity(requests);
    let mut expected = Vec::with_capacity(requests);
    for _ in 0..requests {
        let kernel = *rng.choose(&names);
        let g = &coord.registry().get(kernel).unwrap().dfg;
        let inputs: Vec<i32> = (0..g.inputs().len())
            .map(|_| rng.range_i64(-1000, 1000) as i32)
            .collect();
        expected.push(crate::dfg::eval(g, &inputs));
        rxs.push(coord.submit(kernel, inputs)?);
    }
    let mut errors = 0usize;
    for (rx, want) in rxs.into_iter().zip(expected) {
        match rx.recv() {
            Ok(Ok(got)) if got == want => {}
            _ => errors += 1,
        }
    }
    println!("{}", coord.metrics_report());
    coord.shutdown()?;
    if errors > 0 {
        anyhow::bail!("{errors} requests returned wrong results");
    }
    println!("all responses verified against the functional oracle");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coordinator_for(backend: BackendKind, workers: usize, max_batch: usize) -> Coordinator {
        let mut cfg = CoordinatorConfig::new(backend);
        cfg.workers = workers;
        cfg.max_batch = max_batch;
        Coordinator::start_with(cfg).unwrap()
    }

    fn sim_coordinator(workers: usize, max_batch: usize) -> Coordinator {
        coordinator_for(BackendKind::Sim, workers, max_batch)
    }

    fn mixed_workload(coord: &Coordinator, requests: usize, seed: u64) {
        let mut rng = Rng::new(seed);
        let names = bench_suite::all_names();
        let mut jobs = Vec::new();
        for _ in 0..requests {
            let kernel = *rng.choose(&names);
            let g = &coord.registry().get(kernel).unwrap().dfg;
            let inputs: Vec<i32> = (0..g.inputs().len())
                .map(|_| rng.range_i64(-500, 500) as i32)
                .collect();
            let want = crate::dfg::eval(g, &inputs);
            let rx = coord.submit(kernel, inputs).unwrap();
            jobs.push((rx, want));
        }
        for (rx, want) in jobs {
            assert_eq!(rx.recv().unwrap().unwrap(), want);
        }
    }

    // ---- sim backend: runs unconditionally, zero artifacts ----------

    #[test]
    fn serves_mixed_workload_correctly() {
        let coord = sim_coordinator(1, 8);
        mixed_workload(&coord, 40, 5);
        assert_eq!(coord.completed(), 40);
        let report = coord.metrics_report();
        assert!(report.contains("context switches"));
        coord.shutdown().unwrap();
    }

    #[test]
    fn call_blocks_for_result() {
        let coord = sim_coordinator(1, 4);
        let out = coord.call("gradient", vec![3, 5, 2, 7, 1]).unwrap();
        assert_eq!(out, vec![1 + 9 + 25 + 1]);
        coord.shutdown().unwrap();
    }

    #[test]
    fn rejects_unknown_kernel_and_bad_arity() {
        let coord = sim_coordinator(1, 4);
        assert!(coord.submit("nonesuch", vec![1]).is_err());
        // Wrong arity surfaces as a structured Err reply, not a hang.
        let r = coord.call("gradient", vec![1, 2]);
        let msg = format!("{}", r.unwrap_err());
        assert!(msg.contains("expects 5 inputs"), "{msg}");
        coord.shutdown().unwrap();
    }

    #[test]
    fn multiple_sim_workers_serve_concurrently() {
        let coord = sim_coordinator(3, 8);
        mixed_workload(&coord, 60, 11);
        assert_eq!(coord.completed(), 60);
        coord.shutdown().unwrap();
    }

    #[test]
    fn ref_backend_serves_too() {
        let coord = coordinator_for(BackendKind::Ref, 2, 16);
        assert_eq!(coord.backend(), BackendKind::Ref);
        mixed_workload(&coord, 30, 7);
        coord.shutdown().unwrap();
    }

    #[test]
    fn turbo_backend_serves_too() {
        let coord = coordinator_for(BackendKind::Turbo, 2, 32);
        assert_eq!(coord.backend(), BackendKind::Turbo);
        mixed_workload(&coord, 50, 13);
        assert_eq!(coord.completed(), 50);
        coord.shutdown().unwrap();
    }

    #[test]
    fn serve_demo_runs_on_sim_without_artifacts() {
        serve_demo(BackendKind::Sim, "/definitely/not/here", 2, 50, 8, 42).unwrap();
    }

    #[test]
    fn serve_demo_runs_on_turbo_without_artifacts() {
        serve_demo(BackendKind::Turbo, "/definitely/not/here", 2, 50, 16, 43).unwrap();
    }

    // ---- PJRT backend: artifact-gated variants ----------------------

    fn artifacts_dir() -> Option<String> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| dir.to_string_lossy().into_owned())
    }

    #[test]
    fn serves_mixed_workload_correctly_pjrt() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let coord = Coordinator::start(&dir, 1, 8).unwrap();
        mixed_workload(&coord, 40, 5);
        assert_eq!(coord.completed(), 40);
        coord.shutdown().unwrap();
    }

    #[test]
    fn call_blocks_for_result_pjrt() {
        let Some(dir) = artifacts_dir() else { return };
        let coord = Coordinator::start(&dir, 1, 4).unwrap();
        let out = coord.call("gradient", vec![3, 5, 2, 7, 1]).unwrap();
        assert_eq!(out, vec![1 + 9 + 25 + 1]);
        coord.shutdown().unwrap();
    }

    #[test]
    fn rejects_unknown_kernel_and_bad_arity_pjrt() {
        let Some(dir) = artifacts_dir() else { return };
        let coord = Coordinator::start(&dir, 1, 4).unwrap();
        assert!(coord.submit("nonesuch", vec![1]).is_err());
        assert!(coord.call("gradient", vec![1, 2]).is_err());
        coord.shutdown().unwrap();
    }

    #[test]
    fn missing_artifacts_fails_fast() {
        assert!(Coordinator::start("/definitely/not/here", 1, 4).is_err());
    }
}
