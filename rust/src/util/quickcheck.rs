//! Mini property-testing harness (no `proptest` in the offline image).
//!
//! Provides seeded random case generation with greedy shrinking for the
//! coordinator/scheduler invariant tests. Usage:
//!
//! ```ignore
//! check(100, gen_vec(gen_i64(-100, 100), 0, 20), |xs| {
//!     prop_assert(xs.iter().sum::<i64>() <= 2000, "sum bound")
//! });
//! ```

use crate::util::prng::Rng;

/// Result of one property evaluation.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: &str) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// A generator produces a value and can propose shrunk variants of it.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values, most aggressive first.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Run `cases` random cases; on failure, greedily shrink and panic with
/// the minimal counterexample. Seed is derived from the property name so
/// failures reproduce across runs.
pub fn check<G: Gen, F>(cases: usize, gen: G, name: &str, prop: F)
where
    F: Fn(&G::Value) -> PropResult,
{
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let v = gen.generate(&mut rng);
        if let Err(msg) = prop(&v) {
            let (min_v, min_msg) = shrink_loop(&gen, v, msg, &prop);
            panic!(
                "property '{name}' failed (case {case}/{cases}): {min_msg}\n  minimal counterexample: {min_v:?}"
            );
        }
    }
}

fn shrink_loop<G: Gen, F>(gen: &G, mut v: G::Value, mut msg: String, prop: &F) -> (G::Value, String)
where
    F: Fn(&G::Value) -> PropResult,
{
    // Bounded greedy shrink.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in gen.shrink(&v) {
            if let Err(m) = prop(&cand) {
                v = cand;
                msg = m;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (v, msg)
}

// ---------------------------------------------------------------------
// Basic generators
// ---------------------------------------------------------------------

/// Uniform i64 in an inclusive range; shrinks toward `lo.max(0).min(hi)`.
pub struct GenI64 {
    pub lo: i64,
    pub hi: i64,
}

pub fn gen_i64(lo: i64, hi: i64) -> GenI64 {
    assert!(lo <= hi);
    GenI64 { lo, hi }
}

impl Gen for GenI64 {
    type Value = i64;
    fn generate(&self, rng: &mut Rng) -> i64 {
        rng.range_i64(self.lo, self.hi)
    }
    fn shrink(&self, v: &i64) -> Vec<i64> {
        let target = 0i64.clamp(self.lo, self.hi);
        let mut out = Vec::new();
        if *v != target {
            out.push(target);
            let mid = target + (v - target) / 2;
            if mid != *v {
                out.push(mid);
            }
            if (v - target).abs() >= 1 {
                out.push(v - (v - target).signum());
            }
        }
        out
    }
}

/// i32 over the full wrapping range (overlay data words).
pub struct GenI32Full;

impl Gen for GenI32Full {
    type Value = i32;
    fn generate(&self, rng: &mut Rng) -> i32 {
        // Mix extremes in, they catch wrapping bugs.
        match rng.index(8) {
            0 => i32::MIN,
            1 => i32::MAX,
            2 => 0,
            3 => -1,
            _ => rng.next_i32(),
        }
    }
    fn shrink(&self, v: &i32) -> Vec<i32> {
        if *v == 0 {
            Vec::new()
        } else {
            vec![0, v / 2]
        }
    }
}

/// Vector of values with a length range; shrinks by halving and by
/// element-wise shrinking of the first shrinkable element.
pub struct GenVec<G> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn gen_vec<G>(inner: G, min_len: usize, max_len: usize) -> GenVec<G> {
    assert!(min_len <= max_len);
    GenVec {
        inner,
        min_len,
        max_len,
    }
}

impl<G: Gen> Gen for GenVec<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        let len = self.min_len + rng.index(self.max_len - self.min_len + 1);
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // Remove back half, then one element.
            let keep = (v.len() / 2).max(self.min_len);
            out.push(v[..keep].to_vec());
            let mut minus_one = v.clone();
            minus_one.pop();
            out.push(minus_one);
        }
        // Shrink first element that offers candidates.
        for (i, x) in v.iter().enumerate() {
            let cands = self.inner.shrink(x);
            if let Some(c) = cands.into_iter().next() {
                let mut w = v.clone();
                w[i] = c;
                out.push(w);
                break;
            }
        }
        out
    }
}

/// Pair generator.
pub struct GenPair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for GenPair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(50, gen_i64(0, 100), "in-range", |v| {
            prop_assert((0..=100).contains(v), "range")
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let caught = std::panic::catch_unwind(|| {
            check(200, gen_i64(0, 1000), "fails-above-50", |v| {
                prop_assert(*v <= 50, "must be <= 50")
            });
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land on the boundary counterexample 51.
        assert!(msg.contains("counterexample: 51"), "{msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let caught = std::panic::catch_unwind(|| {
            check(
                200,
                gen_vec(gen_i64(0, 9), 0, 30),
                "short-vecs-only",
                |v| prop_assert(v.len() < 3, "len < 3"),
            );
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // Minimal failing vec has exactly 3 elements.
        let needle = "minimal counterexample: [";
        let tail = &msg[msg.find(needle).unwrap() + needle.len()..];
        let commas = tail[..tail.find(']').unwrap()].matches(',').count();
        assert_eq!(commas, 2, "{msg}");
    }

    #[test]
    fn deterministic_given_name() {
        // Same property name => same seed => same failure.
        let run = || {
            std::panic::catch_unwind(|| {
                check(100, gen_i64(0, 1_000_000), "det", |v| {
                    prop_assert(*v < 999_999, "bound")
                });
            })
        };
        let a = run().err().map(|e| *e.downcast::<String>().unwrap());
        let b = run().err().map(|e| *e.downcast::<String>().unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn i32_full_hits_extremes() {
        let mut rng = Rng::new(3);
        let g = GenI32Full;
        let vals: Vec<i32> = (0..200).map(|_| g.generate(&mut rng)).collect();
        assert!(vals.contains(&i32::MIN));
        assert!(vals.contains(&i32::MAX));
    }
}
