//! SHA-256 and HMAC-SHA256, written in-repo (the offline image has no
//! crates.io access). Used by the wire layer's tenant tokens: the client
//! signs `tenant || nonce` with a shared secret and the server verifies
//! against its keyring before admitting the connection.
//!
//! The implementation is the straightforward FIPS 180-4 compression
//! function — no SIMD, no streaming API — because the inputs are tiny
//! (a tenant name plus eight nonce bytes) and it runs once per
//! connection, not per request. Correctness is pinned by the FIPS
//! known-answer vectors and the RFC 4231 HMAC test cases below.

/// Round constants: the first 32 bits of the fractional parts of the
/// cube roots of the first 64 primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a_2f98, 0x7137_4491, 0xb5c0_fbcf, 0xe9b5_dba5, 0x3956_c25b, 0x59f1_11f1, 0x923f_82a4,
    0xab1c_5ed5, 0xd807_aa98, 0x1283_5b01, 0x2431_85be, 0x550c_7dc3, 0x72be_5d74, 0x80de_b1fe,
    0x9bdc_06a7, 0xc19b_f174, 0xe49b_69c1, 0xefbe_4786, 0x0fc1_9dc6, 0x240c_a1cc, 0x2de9_2c6f,
    0x4a74_84aa, 0x5cb0_a9dc, 0x76f9_88da, 0x983e_5152, 0xa831_c66d, 0xb003_27c8, 0xbf59_7fc7,
    0xc6e0_0bf3, 0xd5a7_9147, 0x06ca_6351, 0x1429_2967, 0x27b7_0a85, 0x2e1b_2138, 0x4d2c_6dfc,
    0x5338_0d13, 0x650a_7354, 0x766a_0abb, 0x81c2_c92e, 0x9272_2c85, 0xa2bf_e8a1, 0xa81a_664b,
    0xc24b_8b70, 0xc76c_51a3, 0xd192_e819, 0xd699_0624, 0xf40e_3585, 0x106a_a070, 0x19a4_c116,
    0x1e37_6c08, 0x2748_774c, 0x34b0_bcb5, 0x391c_0cb3, 0x4ed8_aa4a, 0x5b9c_ca4f, 0x682e_6ff3,
    0x748f_82ee, 0x78a5_636f, 0x84c8_7814, 0x8cc7_0208, 0x90be_fffa, 0xa450_6ceb, 0xbef9_a3f7,
    0xc671_78f2,
];

/// Initial hash state: the first 32 bits of the fractional parts of the
/// square roots of the first 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09_e667, 0xbb67_ae85, 0x3c6e_f372, 0xa54f_f53a, 0x510e_527f, 0x9b05_688c, 0x1f83_d9ab,
    0x5be0_cd19,
];

fn compress(state: &mut [u32; 8], block: &[u8]) {
    debug_assert_eq!(block.len(), 64);
    let mut w = [0u32; 64];
    for (i, chunk) in block.chunks_exact(4).enumerate() {
        w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *state;
    for i in 0..64 {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = h
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(K[i])
            .wrapping_add(w[i]);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        h = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    state[0] = state[0].wrapping_add(a);
    state[1] = state[1].wrapping_add(b);
    state[2] = state[2].wrapping_add(c);
    state[3] = state[3].wrapping_add(d);
    state[4] = state[4].wrapping_add(e);
    state[5] = state[5].wrapping_add(f);
    state[6] = state[6].wrapping_add(g);
    state[7] = state[7].wrapping_add(h);
}

/// SHA-256 of `msg` (FIPS 180-4).
pub fn sha256(msg: &[u8]) -> [u8; 32] {
    let mut state = H0;
    let mut chunks = msg.chunks_exact(64);
    for block in chunks.by_ref() {
        compress(&mut state, block);
    }

    // Padding: 0x80, zeros to 56 mod 64, then the bit length as u64 BE.
    let tail = chunks.remainder();
    let mut block = [0u8; 128];
    block[..tail.len()].copy_from_slice(tail);
    block[tail.len()] = 0x80;
    let padded = if tail.len() < 56 { 64 } else { 128 };
    let bits = (msg.len() as u64).wrapping_mul(8);
    block[padded - 8..padded].copy_from_slice(&bits.to_be_bytes());
    compress(&mut state, &block[..64]);
    if padded == 128 {
        compress(&mut state, &block[64..]);
    }

    let mut out = [0u8; 32];
    for (i, word) in state.iter().enumerate() {
        out[i * 4..i * 4 + 4].copy_from_slice(&word.to_be_bytes());
    }
    out
}

/// HMAC-SHA256 of `msg` under `key` (RFC 2104).
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }

    let mut inner = Vec::with_capacity(64 + msg.len());
    inner.extend(k.iter().map(|b| b ^ 0x36));
    inner.extend_from_slice(msg);
    let inner_hash = sha256(&inner);

    let mut outer = Vec::with_capacity(64 + 32);
    outer.extend(k.iter().map(|b| b ^ 0x5c));
    outer.extend_from_slice(&inner_hash);
    sha256(&outer)
}

/// Constant-time equality for two MACs: XOR-accumulate every byte pair
/// so the comparison's timing does not leak the first differing index.
pub fn mac_eq(a: &[u8; 32], b: &[u8; 32]) -> bool {
    a.iter().zip(b.iter()).fold(0u8, |acc, (x, y)| acc | (x ^ y)) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn sha256_known_answers() {
        // FIPS 180-4 known-answer vectors.
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(
                b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"
            )),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn sha256_padding_boundaries() {
        // 55/56/63/64-byte messages cross the one-vs-two padding-block
        // boundary; compare against python3 hashlib.
        assert_eq!(
            hex(&sha256(&[b'a'; 55])),
            "9f4390f8d30c2dd92ec9f095b65e2b9ae9b0a925a5258e241c9f1e910f734318"
        );
        assert_eq!(
            hex(&sha256(&[b'a'; 56])),
            "b35439a4ac6f0948b6d6f9e3c6af0f5f590ce20f1bde7090ef7970686ec6738a"
        );
        assert_eq!(
            hex(&sha256(&[b'a'; 64])),
            "ffe054fe7ae0cb6dc65c3af9b61d5209f439851db43d0ba5997337df154668eb"
        );
    }

    #[test]
    fn hmac_rfc4231_vectors() {
        // RFC 4231 test case 1.
        assert_eq!(
            hex(&hmac_sha256(&[0x0b; 20], b"Hi There")),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
        // RFC 4231 test case 2 (short ASCII key).
        assert_eq!(
            hex(&hmac_sha256(b"Jefe", b"what do ya want for nothing?")),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
        // RFC 4231 test case 6 (key longer than one block, hashed first).
        assert_eq!(
            hex(&hmac_sha256(
                &[0xaa; 131],
                b"Test Using Larger Than Block-Size Key - Hash Key First"
            )),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn mac_eq_is_exact() {
        let a = sha256(b"x");
        let mut b = a;
        assert!(mac_eq(&a, &b));
        b[31] ^= 1;
        assert!(!mac_eq(&a, &b));
        b[31] ^= 1;
        b[0] ^= 0x80;
        assert!(!mac_eq(&a, &b));
    }
}
