//! Bit-level packing helpers for the overlay ISA.
//!
//! The FU instruction is a 32-bit word with explicit DSP48E1 control
//! fields (no decoders in the hardware — the bits drive the primitive
//! directly), and the context stream is 40-bit words. These helpers give
//! checked field insert/extract over `u64` containers.

/// Insert `value` into `word` at `[lsb, lsb+width)`. Panics if the value
/// does not fit the field or the field exceeds the container.
#[inline]
pub fn set_field(word: u64, lsb: u32, width: u32, value: u64) -> u64 {
    assert!(width >= 1 && width <= 64, "field width {width}");
    assert!(lsb + width <= 64, "field [{lsb},{})", lsb + width);
    let mask = mask(width);
    assert!(value <= mask, "value {value:#x} exceeds {width}-bit field");
    (word & !(mask << lsb)) | (value << lsb)
}

/// Extract the `[lsb, lsb+width)` field.
#[inline]
pub fn get_field(word: u64, lsb: u32, width: u32) -> u64 {
    assert!(width >= 1 && width <= 64);
    assert!(lsb + width <= 64);
    (word >> lsb) & mask(width)
}

/// All-ones mask of `width` bits.
#[inline]
pub fn mask(width: u32) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

/// A little-endian bit stream writer used to serialize context memory
/// images (sequences of 40-bit words) into bytes.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    bytes: Vec<u8>,
    /// Number of valid bits in the last byte (0 == byte-aligned).
    bit_pos: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append the low `width` bits of `value`.
    pub fn push(&mut self, value: u64, width: u32) {
        assert!(width <= 64);
        assert!(width == 64 || value <= mask(width), "value does not fit");
        let mut remaining = width;
        let mut v = value;
        while remaining > 0 {
            if self.bit_pos == 0 {
                self.bytes.push(0);
            }
            let space = 8 - self.bit_pos;
            let take = space.min(remaining);
            let chunk = (v & mask(take)) as u8;
            let last = self.bytes.last_mut().unwrap();
            *last |= chunk << self.bit_pos;
            self.bit_pos = (self.bit_pos + take) % 8;
            v >>= take;
            remaining -= take;
        }
    }

    pub fn len_bits(&self) -> usize {
        if self.bit_pos == 0 {
            self.bytes.len() * 8
        } else {
            (self.bytes.len() - 1) * 8 + self.bit_pos as usize
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }
}

/// Matching little-endian bit stream reader.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos_bits: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos_bits: 0 }
    }

    pub fn remaining_bits(&self) -> usize {
        self.bytes.len() * 8 - self.pos_bits
    }

    /// Read `width` bits; returns `None` past the end.
    pub fn read(&mut self, width: u32) -> Option<u64> {
        assert!(width <= 64);
        if self.remaining_bits() < width as usize {
            return None;
        }
        let mut out: u64 = 0;
        let mut got = 0u32;
        while got < width {
            let byte = self.bytes[self.pos_bits / 8];
            let bit_off = (self.pos_bits % 8) as u32;
            let avail = 8 - bit_off;
            let take = avail.min(width - got);
            let chunk = ((byte >> bit_off) as u64) & mask(take);
            out |= chunk << got;
            got += take;
            self.pos_bits += take as usize;
        }
        Some(out)
    }
}

/// Count of ones — used by resource estimators for constant-multiplier
/// strength-reduction cost (adders per set bit in CSD-lite form).
pub fn popcount_u64(v: u64) -> u32 {
    v.count_ones()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_round_trip() {
        let mut w = 0u64;
        w = set_field(w, 0, 5, 0b10101);
        w = set_field(w, 5, 5, 0b01010);
        w = set_field(w, 10, 21, 0x1F_FF00);
        assert_eq!(get_field(w, 0, 5), 0b10101);
        assert_eq!(get_field(w, 5, 5), 0b01010);
        assert_eq!(get_field(w, 10, 21), 0x1F_FF00);
    }

    #[test]
    fn field_overwrite_clears_old_bits() {
        let w = set_field(u64::MAX, 8, 8, 0x00);
        assert_eq!(get_field(w, 8, 8), 0);
        assert_eq!(get_field(w, 0, 8), 0xFF);
        assert_eq!(get_field(w, 16, 8), 0xFF);
    }

    #[test]
    #[should_panic(expected = "exceeds")]
    fn field_value_too_wide_panics() {
        set_field(0, 0, 3, 8);
    }

    #[test]
    fn bitstream_round_trip_40bit_words() {
        let words: Vec<u64> = vec![0x55_AAAA_5555, 0xFF_0000_00FF, 0x00_1234_5678];
        let mut w = BitWriter::new();
        for &v in &words {
            w.push(v, 40);
        }
        assert_eq!(w.len_bits(), 120);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), 15);
        let mut r = BitReader::new(&bytes);
        for &v in &words {
            assert_eq!(r.read(40), Some(v));
        }
        assert_eq!(r.read(1), None);
    }

    #[test]
    fn bitstream_mixed_widths() {
        let mut w = BitWriter::new();
        w.push(0b1, 1);
        w.push(0b1011, 4);
        w.push(0xDEADBEEF, 32);
        w.push(0x3FF, 10);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(1), Some(1));
        assert_eq!(r.read(4), Some(0b1011));
        assert_eq!(r.read(32), Some(0xDEADBEEF));
        assert_eq!(r.read(10), Some(0x3FF));
    }

    #[test]
    fn read_past_end_is_none() {
        let mut r = BitReader::new(&[0xFF]);
        assert_eq!(r.read(8), Some(0xFF));
        assert_eq!(r.read(1), None);
    }

    #[test]
    fn mask_widths() {
        assert_eq!(mask(1), 1);
        assert_eq!(mask(5), 31);
        assert_eq!(mask(64), u64::MAX);
    }
}
