//! Deterministic pseudo-random number generation.
//!
//! The image has no `rand` crate, so workload generation, property tests
//! and the serving trace use this small SplitMix64 + xoshiro256** pair
//! (public-domain algorithms by Vigna/Steele et al.). All generators are
//! seeded explicitly — every experiment in the repo is reproducible.

/// SplitMix64: used for seeding and cheap hashing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the main generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Wrapping int32 sample over the full range (overlay data words).
    pub fn next_i32(&mut self) -> i32 {
        self.next_u32() as i32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_wide(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform i64 in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed sample with the given rate (Poisson
    /// inter-arrival gaps for the serving workload).
    pub fn exp(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[inline]
fn mul_wide(a: u64, b: u64) -> (u64, u64) {
    let w = (a as u128) * (b as u128);
    ((w >> 64) as u64, w as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_both_ends() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(11);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(13);
        let rate = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn index_covers_small_domain() {
        let mut r = Rng::new(19);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.index(5)] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }
}
