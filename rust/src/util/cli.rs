//! Tiny CLI argument parser (no `clap` in the offline image).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Unknown flags are an error; `--help` is generated from
//! the declared options.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Declared option (for help text and validation).
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    takes_value: bool,
    help: String,
    default: Option<String>,
}

/// A declarative command spec.
#[derive(Debug, Clone, Default)]
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positional: Vec<(String, String)>, // (name, help)
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Self {
            name: name.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            takes_value: false,
            help: help.to_string(),
            default: None,
        });
        self
    }

    pub fn opt(mut self, name: &str, help: &str, default: Option<&str>) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            takes_value: true,
            help: help.to_string(),
            default: default.map(str::to_string),
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional.push((name.to_string(), help.to_string()));
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }
    pub fn about(&self) -> &str {
        &self.about
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  tmfu {}", self.name, self.about, self.name);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        if !self.positional.is_empty() {
            s.push_str("\n\nARGS:\n");
            for (p, h) in &self.positional {
                s.push_str(&format!("  <{p:<14}> {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\n\nOPTIONS:\n");
            for o in &self.opts {
                let lhs = if o.takes_value {
                    format!("--{} <v>", o.name)
                } else {
                    format!("--{}", o.name)
                };
                let dflt = o
                    .default
                    .as_ref()
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                s.push_str(&format!("  {lhs:<22} {}{dflt}\n", o.help));
            }
        }
        s
    }

    /// Parse `args` (not including the command name itself).
    pub fn parse(&self, args: &[String]) -> Result<Matches, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut pos: Vec<String> = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}\n\n{}", self.usage())))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError(format!("--{key} requires a value")))?,
                    };
                    values.insert(key, v);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{key} does not take a value")));
                    }
                    flags.push(key);
                }
            } else {
                pos.push(a.clone());
            }
        }
        if pos.len() < self.positional.len() {
            return Err(CliError(format!(
                "missing required argument <{}>\n\n{}",
                self.positional[pos.len()].0,
                self.usage()
            )));
        }
        if pos.len() > self.positional.len() {
            return Err(CliError(format!(
                "unexpected positional argument '{}'",
                pos[self.positional.len()]
            )));
        }
        // Apply defaults.
        for o in &self.opts {
            if o.takes_value && !values.contains_key(&o.name) {
                if let Some(d) = &o.default {
                    values.insert(o.name.clone(), d.clone());
                }
            }
        }
        let positional = self
            .positional
            .iter()
            .map(|(n, _)| n.clone())
            .zip(pos)
            .collect();
        Ok(Matches {
            values,
            flags,
            positional,
        })
    }
}

/// Parsed results.
#[derive(Debug, Clone)]
pub struct Matches {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: BTreeMap<String, String>,
}

impl Matches {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_pos(&self, name: &str) -> Option<&str> {
        self.positional.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: '{v}' is not a valid integer"))),
        }
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| CliError(format!("--{name}: '{v}' is not a valid number"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn demo() -> Command {
        Command::new("simulate", "run the cycle simulator")
            .positional("kernel", "benchmark name")
            .opt("batches", "number of data batches", Some("4"))
            .opt("seed", "prng seed", None)
            .flag("trace", "dump cycle trace")
    }

    #[test]
    fn parses_positional_and_defaults() {
        let m = demo().parse(&args(&["gradient"])).unwrap();
        assert_eq!(m.get_pos("kernel"), Some("gradient"));
        assert_eq!(m.get_usize("batches").unwrap(), Some(4));
        assert_eq!(m.get("seed"), None);
        assert!(!m.flag("trace"));
    }

    #[test]
    fn parses_key_value_both_styles() {
        let m = demo()
            .parse(&args(&["gradient", "--batches=9", "--seed", "17", "--trace"]))
            .unwrap();
        assert_eq!(m.get_usize("batches").unwrap(), Some(9));
        assert_eq!(m.get("seed"), Some("17"));
        assert!(m.flag("trace"));
    }

    #[test]
    fn rejects_unknown_and_missing() {
        assert!(demo().parse(&args(&["gradient", "--nope"])).is_err());
        assert!(demo().parse(&args(&[])).is_err());
        assert!(demo().parse(&args(&["a", "b"])).is_err());
        assert!(demo().parse(&args(&["gradient", "--seed"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(demo().parse(&args(&["gradient", "--trace=1"])).is_err());
    }

    #[test]
    fn help_lists_options() {
        let err = demo().parse(&args(&["--help"])).unwrap_err();
        assert!(err.0.contains("--batches"));
        assert!(err.0.contains("USAGE"));
    }

    #[test]
    fn numeric_validation() {
        let m = demo().parse(&args(&["g", "--batches", "abc"])).unwrap();
        assert!(m.get_usize("batches").is_err());
    }

    #[test]
    fn get_usize_error_paths() {
        // Missing optional value: Ok(None), not an error.
        let m = demo().parse(&args(&["g"])).unwrap();
        assert_eq!(m.get_usize("seed").unwrap(), None);
        assert_eq!(m.get_f64("seed").unwrap(), None);
        // Negative and overflowing values are parse errors with the
        // offending flag named.
        let m = demo().parse(&args(&["g", "--batches", "-3"])).unwrap();
        let err = m.get_usize("batches").unwrap_err();
        assert!(err.to_string().contains("--batches"), "{err}");
        assert!(err.to_string().contains("-3"), "{err}");
        let m = demo()
            .parse(&args(&["g", "--batches", "99999999999999999999999999"]))
            .unwrap();
        assert!(m.get_usize("batches").is_err());
        // get_f64 accepts what get_usize rejects (and vice versa).
        let m = demo().parse(&args(&["g", "--batches", "2.5"])).unwrap();
        assert!(m.get_usize("batches").is_err());
        assert_eq!(m.get_f64("batches").unwrap(), Some(2.5));
        let m = demo().parse(&args(&["g", "--batches", "x"])).unwrap();
        assert!(m.get_f64("batches").is_err());
    }

    #[test]
    fn defaults_do_not_override_explicit_values() {
        // Default applies only when the flag is absent.
        let m = demo().parse(&args(&["g"])).unwrap();
        assert_eq!(m.get("batches"), Some("4"));
        let m = demo().parse(&args(&["g", "--batches", "7"])).unwrap();
        assert_eq!(m.get("batches"), Some("7"));
        // =-style wins the same way; an empty value is kept as-is.
        let m = demo().parse(&args(&["g", "--batches="])).unwrap();
        assert_eq!(m.get("batches"), Some(""));
        assert!(m.get_usize("batches").is_err());
        // Last occurrence wins when a flag repeats.
        let m = demo()
            .parse(&args(&["g", "--batches", "1", "--batches", "9"]))
            .unwrap();
        assert_eq!(m.get_usize("batches").unwrap(), Some(9));
    }

    #[test]
    fn positionals_interleave_with_options() {
        // Options may appear before, between, or after positionals.
        let two = Command::new("cp", "copy")
            .positional("src", "source")
            .positional("dst", "destination")
            .opt("mode", "copy mode", Some("fast"))
            .flag("verbose", "chatty");
        let m = two
            .parse(&args(&["--mode", "slow", "a.txt", "--verbose", "b.txt"]))
            .unwrap();
        assert_eq!(m.get_pos("src"), Some("a.txt"));
        assert_eq!(m.get_pos("dst"), Some("b.txt"));
        assert_eq!(m.get("mode"), Some("slow"));
        assert!(m.flag("verbose"));
        // Unknown positional name lookups are None, not panics.
        assert_eq!(m.get_pos("nonesuch"), None);
        // Missing second positional names the gap.
        let err = two.parse(&args(&["only"])).unwrap_err();
        assert!(err.to_string().contains("<dst>"), "{err}");
        // Extra positionals are rejected with the offender.
        let err = two.parse(&args(&["a", "b", "c"])).unwrap_err();
        assert!(err.to_string().contains("'c'"), "{err}");
    }

    #[test]
    fn unknown_flag_suggests_usage() {
        let err = demo().parse(&args(&["g", "--nope"])).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown option --nope"), "{msg}");
        // The usage block rides along so the user sees what's legal.
        assert!(msg.contains("USAGE"), "{msg}");
        assert!(msg.contains("--batches"), "{msg}");
        // Value-style unknown flags are rejected too.
        assert!(demo().parse(&args(&["g", "--nope=3"])).is_err());
    }

    #[test]
    fn usage_lists_positionals_defaults_and_flags() {
        let u = demo().usage();
        assert!(u.contains("<kernel"), "{u}");
        assert!(u.contains("[default: 4]"), "{u}");
        assert!(u.contains("--trace"), "{u}");
        // No default annotation for defaultless opts.
        let seed_line = u.lines().find(|l| l.contains("--seed")).unwrap();
        assert!(!seed_line.contains("default"), "{seed_line}");
    }
}
