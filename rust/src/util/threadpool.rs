//! Fixed-size thread pool (no tokio in the offline image).
//!
//! The coordinator uses one logical worker per overlay pipeline plus a
//! dispatcher; this pool provides the generic fan-out/fan-in primitive
//! with panic propagation and clean shutdown.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads consuming from a shared queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let in_flight = Arc::new(AtomicUsize::new(0));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let in_flight = Arc::clone(&in_flight);
                thread::Builder::new()
                    .name(format!("tmfu-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                in_flight.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed: shutdown
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Some(tx),
            workers,
            in_flight,
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Number of jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Block until the queue drains (busy-wait with yield; fine for the
    /// bench/test scales used here).
    pub fn wait_idle(&self) {
        while self.in_flight() > 0 {
            thread::yield_now();
        }
    }

    /// Parallel map that preserves input order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang or panic
    }

    #[test]
    fn single_worker_is_sequential_queue() {
        let pool = ThreadPool::new(1);
        let log = Arc::new(Mutex::new(Vec::new()));
        for i in 0..10 {
            let log = Arc::clone(&log);
            pool.execute(move || log.lock().unwrap().push(i));
        }
        pool.wait_idle();
        assert_eq!(*log.lock().unwrap(), (0..10).collect::<Vec<_>>());
    }
}
