//! Poison-tolerant locking (DESIGN.md §12, `tools/source_lint.py`).
//!
//! `Mutex::lock().unwrap()` turns one panicked thread into a cascade:
//! every later locker panics on the `PoisonError` even though the
//! protected data is still structurally valid (every critical section
//! in this crate either completes its writes or leaves state a reader
//! can safely observe — counters, maps, wakers; none do multi-step
//! invariant surgery mid-section). The runtime therefore standardises
//! on [`LockExt::lock_unpoisoned`], which recovers the guard from a
//! poisoned mutex and carries on. `tools/source_lint.py` bans the
//! `.lock().unwrap()` / `.lock().expect(...)` spelling in `wire/`,
//! `router/` and `coordinator/` so the recovery idiom cannot silently
//! regress.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Extension trait: acquire a mutex, shrugging off poison.
pub trait LockExt<T> {
    /// Like [`Mutex::lock`], but a poisoned mutex (some thread panicked
    /// while holding the guard) yields the guard anyway instead of
    /// panicking the caller too.
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T>;
}

impl<T> LockExt<T> for Mutex<T> {
    fn lock_unpoisoned(&self) -> MutexGuard<'_, T> {
        self.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn plain_lock_still_works() {
        let m = Mutex::new(3);
        *m.lock_unpoisoned() += 4;
        assert_eq!(*m.lock_unpoisoned(), 7);
    }

    #[test]
    fn poisoned_mutex_is_recovered_not_propagated() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        // Poison it: panic while holding the guard on another thread.
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        // lock_unpoisoned still hands out the (intact) data.
        let g = m.lock_unpoisoned();
        assert_eq!(*g, vec![1, 2, 3]);
    }
}
