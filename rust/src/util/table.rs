//! ASCII table rendering for benchmark/report output.
//!
//! Every table and figure the benches regenerate is printed through this
//! formatter so paper-vs-measured comparisons line up in the terminal and
//! in `bench_output.txt`. Also emits CSV for downstream plotting.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder: header + rows of strings.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: Option<String>,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self {
            title: Some(title.to_string()),
            ..Default::default()
        }
    }

    pub fn untitled() -> Self {
        Self::default()
    }

    /// Set the header; columns default to right-aligned except the first.
    pub fn header<S: AsRef<str>>(mut self, cols: &[S]) -> Self {
        self.header = cols.iter().map(|c| c.as_ref().to_string()).collect();
        self.aligns = (0..self.header.len())
            .map(|i| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        self
    }

    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row<S: AsRef<str>>(&mut self, cols: &[S]) -> &mut Self {
        assert_eq!(
            cols.len(),
            self.header.len(),
            "row width {} != header width {}",
            cols.len(),
            self.header.len()
        );
        self.rows.push(cols.iter().map(|c| c.as_ref().to_string()).collect());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                w[i] = w[i].max(cell.chars().count());
            }
        }
        w
    }

    /// Render with unicode-free box drawing (pipes and dashes).
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&self.render_row(&self.header, &w, true));
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&self.render_row(row, &w, false));
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    fn render_row(&self, cells: &[String], w: &[usize], is_header: bool) -> String {
        let mut line = String::from("|");
        for (i, cell) in cells.iter().enumerate() {
            let pad = w[i] - cell.chars().count();
            let (l, r) = if is_header || self.aligns[i] == Align::Left {
                (0, pad)
            } else {
                (pad, 0)
            };
            line.push(' ');
            line.push_str(&" ".repeat(l));
            line.push_str(cell);
            line.push_str(&" ".repeat(r));
            line.push(' ');
            line.push('|');
        }
        line.push('\n');
        line
    }

    /// CSV emission (RFC-4180 quoting).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let quote = |c: &str| -> String {
            if c.contains([',', '"', '\n']) {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        out.push_str(&self.header.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| quote(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Horizontal ASCII bar chart — used for the Fig. 5 / Fig. 6 renderings.
pub struct BarChart {
    title: String,
    entries: Vec<(String, Vec<(String, f64)>)>, // group -> series values
    width: usize,
}

impl BarChart {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            entries: Vec::new(),
            width: 50,
        }
    }

    pub fn width(mut self, w: usize) -> Self {
        self.width = w;
        self
    }

    /// Add a group (e.g. a benchmark) with one bar per series.
    pub fn group(&mut self, name: &str, series: &[(&str, f64)]) -> &mut Self {
        self.entries.push((
            name.to_string(),
            series.iter().map(|(s, v)| (s.to_string(), *v)).collect(),
        ));
        self
    }

    pub fn render(&self) -> String {
        let max = self
            .entries
            .iter()
            .flat_map(|(_, s)| s.iter().map(|(_, v)| *v))
            .fold(0.0f64, f64::max)
            .max(1e-12);
        let label_w = self
            .entries
            .iter()
            .flat_map(|(g, s)| s.iter().map(move |(n, _)| g.chars().count() + n.chars().count() + 1))
            .max()
            .unwrap_or(8);
        let mut out = format!("{}\n", self.title);
        for (group, series) in &self.entries {
            for (name, v) in series {
                let label = format!("{group}/{name}");
                let bar_len = ((v / max) * self.width as f64).round() as usize;
                out.push_str(&format!(
                    "  {:<label_w$} |{:<width$}| {:.2}\n",
                    label,
                    "#".repeat(bar_len),
                    v,
                    label_w = label_w,
                    width = self.width
                ));
            }
        }
        out
    }
}

/// Format helper: fixed decimals.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Format helper: ratio as "N.NNx".
pub fn fmt_ratio(v: f64) -> String {
    format!("{v:.2}x")
}

/// Format helper: percent delta between measured and reference.
pub fn fmt_delta_pct(measured: f64, reference: f64) -> String {
    if reference == 0.0 {
        return "n/a".to_string();
    }
    format!("{:+.1}%", (measured - reference) / reference * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("Demo").header(&["name", "value"]);
        t.row(&["alpha", "1"]);
        t.row(&["b", "100"]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("| alpha |     1 |"));
        assert!(s.contains("| b     |   100 |"));
        // All lines between pluses have equal width.
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::untitled().header(&["a", "b"]);
        t.row(&["only-one"]);
    }

    #[test]
    fn csv_quotes_properly() {
        let mut t = Table::untitled().header(&["k", "v"]);
        t.row(&["has,comma", "has\"quote"]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().nth(1).unwrap(), "\"has,comma\",\"has\"\"quote\"");
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let mut c = BarChart::new("t").width(10);
        c.group("g", &[("a", 10.0), ("b", 5.0)]);
        let s = c.render();
        assert!(s.contains("##########"), "{s}");
        assert!(s.contains("#####"), "{s}");
    }

    #[test]
    fn delta_pct() {
        assert_eq!(fmt_delta_pct(110.0, 100.0), "+10.0%");
        assert_eq!(fmt_delta_pct(90.0, 100.0), "-10.0%");
        assert_eq!(fmt_delta_pct(1.0, 0.0), "n/a");
    }
}
