//! Minimal, dependency-free JSON codec.
//!
//! The offline build image ships no `serde`/`serde_json`, so the DFG /
//! schedule / manifest interchange between the Rust coordinator and the
//! Python compile path uses this small, well-tested implementation.
//!
//! Supported: the full JSON data model (objects, arrays, strings, numbers,
//! booleans, null), UTF-8 input, `\uXXXX` escapes (including surrogate
//! pairs), and pretty or compact emission. Numbers are kept as `f64` plus
//! an integer fast path (`Json::Int`) so 64-bit ids round-trip exactly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Integers that fit in `i64` are kept exact.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order so emitted artifacts
    /// are byte-stable across runs.
    Obj(BTreeMap<String, Json>),
}

/// Error raised by [`parse`], with byte offset and a short message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9.2e18 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indents.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(n) => {
                if n.is_finite() {
                    // Shortest round-trip float formatting.
                    out.push_str(&format!("{n}"));
                    if n.fract() == 0.0 && !out.ends_with(|c: char| c == '.' || c == 'e') {
                        // `{}` prints 2.0 as "2"; keep it a float token.
                        if !out[out.rfind(|c: char| !c.is_ascii_digit() && c != '-').map_or(0, |i| i + 1)..]
                            .contains('.')
                        {
                            out.push_str(".0");
                        }
                    }
                } else {
                    // JSON has no NaN/Inf; encode as null (documented).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..(w * level) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors used by the emitters.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn i(v: i64) -> Json {
    Json::Int(v)
}
pub fn f(v: f64) -> Json {
    Json::Num(v)
}
pub fn ints<I: IntoIterator<Item = i64>>(items: I) -> Json {
    Json::Arr(items.into_iter().map(Json::Int).collect())
}
pub fn strs<'a, I: IntoIterator<Item = &'a str>>(items: I) -> Json {
    Json::Arr(items.into_iter().map(s).collect())
}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage rejected.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, text: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (expected {text})")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{08}'),
                    Some(b'f') => out.push('\u{0c}'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            hi as u32
                        };
                        out.push(char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8 sequence"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8 in string"))?;
                        out.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        let mut v: u16 = 0;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("invalid hex digit"))?;
            v = (v << 4) | d as u16;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if text.is_empty() || text == "-" {
            return Err(self.err("invalid number"));
        }
        if !is_float {
            if let Ok(v) = text.parse::<i64>() {
                return Ok(Json::Int(v));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("2.5").unwrap(), Json::Num(2.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(1).as_i64(), Some(2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn escapes_round_trip() {
        let cases = ["a\"b", "line\nbreak", "tab\there", "uni\u{263A}code", "back\\slash"];
        for c in cases {
            let j = Json::Str(c.to_string());
            let enc = j.to_string_compact();
            assert_eq!(parse(&enc).unwrap(), j, "case {c:?} enc {enc}");
        }
    }

    #[test]
    fn surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
        assert!(parse(r#""\ud83d""#).is_err());
        assert!(parse(r#""\udc00""#).is_err());
    }

    #[test]
    fn big_ints_exact() {
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_i64(), Some(9007199254740993));
        assert_eq!(v.to_string_compact(), "9007199254740993");
    }

    #[test]
    fn object_order_deterministic() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"m":3,"z":1}"#);
    }

    #[test]
    fn pretty_round_trips() {
        let v = obj(vec![
            ("name", s("gradient")),
            ("ops", ints([1, 2, 3])),
            ("ratio", f(1.5)),
            ("ok", Json::Bool(true)),
        ]);
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn float_tokens_stay_floats() {
        let v = Json::Num(2.0);
        let enc = v.to_string_compact();
        assert_eq!(parse(&enc).unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn deep_nesting() {
        let mut src = String::new();
        for _ in 0..200 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..200 {
            src.push(']');
        }
        let mut v = &parse(&src).unwrap();
        for _ in 0..200 {
            v = v.at(0);
        }
        assert_eq!(v.as_i64(), Some(1));
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"héllo wörld ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld ☃"));
    }
}
