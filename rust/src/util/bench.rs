//! Self-timed micro-benchmark harness (no `criterion` in the offline
//! image). Used by the `rust/benches/*` targets (all `harness = false`).
//!
//! Methodology: warm up for a fixed duration, then run timed batches
//! until a target measurement time elapses; report mean/p50/min over
//! per-iteration times with outlier-robust stats from `util::stats`.
//!
//! Besides the human-readable report lines, benches can collect
//! measurements into a [`BenchReport`] and emit machine-readable JSON
//! (`--json <path>`, see [`json_path_from_args`]) — the perf
//! trajectory files (`BENCH_PR2.json`, ...) checked in at the repo
//! root are produced this way by `make bench`.

use crate::util::json::{self, Json};
use crate::util::stats::Samples;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    /// Optional throughput annotation (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// items/second, if items_per_iter was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.mean_ns * 1e-9))
    }

    /// Machine-readable form (one object per measurement).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", json::s(&self.name)),
            ("iters", json::i(self.iters as i64)),
            ("mean_ns", json::f(self.mean_ns)),
            ("p50_ns", json::f(self.p50_ns)),
            ("min_ns", json::f(self.min_ns)),
        ];
        if let Some(items) = self.items_per_iter {
            pairs.push(("items_per_iter", json::f(items)));
        }
        if let Some(tput) = self.throughput() {
            pairs.push(("items_per_s", json::f(tput)));
        }
        json::obj(pairs)
    }

    pub fn report_line(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Mitems/s", t / 1e6),
            Some(t) => format!("  {:>10.0} items/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12}/iter (p50 {:>12}, min {:>12}, n={}){}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.min_ns),
            self.iters,
            tput
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}us", ns / 1e3)
    } else {
        format!("{ns:.0}")
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
        }
    }

    /// Honour `TMFU_BENCH_FAST=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("TMFU_BENCH_FAST").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Time `f`, which performs ONE logical iteration per call. A returned
    /// value is black-boxed to keep the optimizer honest.
    pub fn run<R, F: FnMut() -> R>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Samples::new();
        let mut iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < self.measure || iters < self.min_iters {
            let it = Instant::now();
            black_box(f());
            samples.push(it.elapsed().as_nanos() as f64);
            iters += 1;
        }
        Measurement {
            name: name.to_string(),
            iters,
            mean_ns: samples.mean(),
            p50_ns: samples.percentile(50.0),
            min_ns: samples.min(),
            items_per_iter: None,
        }
    }

    /// Like `run` but annotates throughput.
    pub fn run_with_items<R, F: FnMut() -> R>(
        &self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> Measurement {
        let mut m = self.run(name, f);
        m.items_per_iter = Some(items_per_iter);
        m
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Heap-allocation counter for allocation-free hot-path audits.
///
/// Install it as the global allocator in a bench binary:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: tmfu_overlay::util::bench::CountingAlloc =
///     tmfu_overlay::util::bench::CountingAlloc;
/// ```
///
/// then diff [`alloc_count`] around a measured region. Counts
/// `alloc`/`alloc_zeroed`/`realloc` events process-wide (all threads).
/// For audits that must be **exact** while other threads (service
/// workers) run, diff [`thread_alloc_count`] instead — it counts only
/// the calling thread's allocations, so a submit-path audit is not
/// polluted by worker-side batch bookkeeping on other threads. When
/// the allocator is *not* installed both counters simply stay at zero.
pub struct CountingAlloc;

static ALLOCATIONS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

thread_local! {
    // const-initialized Cell: accessing it never allocates (no lazy
    // init), which matters inside a global allocator. No destructor,
    // so no TLS-teardown reentrancy either.
    static THREAD_ALLOCATIONS: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Allocation events observed so far by [`CountingAlloc`], all threads.
pub fn alloc_count() -> u64 {
    ALLOCATIONS.load(std::sync::atomic::Ordering::Relaxed)
}

/// Allocation events observed so far by [`CountingAlloc`] **on the
/// calling thread** — the exact-zero steady-state audits use this.
pub fn thread_alloc_count() -> u64 {
    THREAD_ALLOCATIONS.with(|c| c.get())
}

fn count_allocation() {
    ALLOCATIONS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    // try_with: never panic inside the allocator, even during thread
    // teardown edge states.
    let _ = THREAD_ALLOCATIONS.try_with(|c| c.set(c.get() + 1));
}

unsafe impl std::alloc::GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: std::alloc::Layout) -> *mut u8 {
        count_allocation();
        // SAFETY: same contract as the caller's — layout is forwarded
        // unchanged to the system allocator.
        unsafe { std::alloc::System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: std::alloc::Layout) -> *mut u8 {
        count_allocation();
        // SAFETY: layout forwarded unchanged.
        unsafe { std::alloc::System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: std::alloc::Layout,
        new_size: usize,
    ) -> *mut u8 {
        count_allocation();
        // SAFETY: ptr/layout/new_size forwarded unchanged.
        unsafe { std::alloc::System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: std::alloc::Layout) {
        // SAFETY: ptr/layout forwarded unchanged.
        unsafe { std::alloc::System.dealloc(ptr, layout) }
    }
}

/// Live OS threads in this process (`/proc/self/status` `Threads:`
/// on Linux; `None` where unavailable). The serving benches and the
/// wire tests use this to assert that in-flight scaling costs
/// O(workers + connections) threads — never a thread per call.
pub fn os_thread_count() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:").and_then(|v| v.trim().parse().ok()))
}

/// Collects measurements plus free-form metadata for the `--json`
/// mode. The emitted shape is stable:
/// `{ meta: {...}, measurements: [Measurement::to_json(), ...] }`.
#[derive(Debug, Default)]
pub struct BenchReport {
    meta: Vec<(String, Json)>,
    measurements: Vec<Measurement>,
}

impl BenchReport {
    pub fn new() -> BenchReport {
        BenchReport::default()
    }

    /// Attach a metadata value (harness id, batch sizes, headline
    /// ratios...). Later writes with the same key win.
    pub fn set_meta(&mut self, key: &str, value: Json) {
        self.meta.retain(|(k, _)| k != key);
        self.meta.push((key.to_string(), value));
    }

    /// Record a measurement (also returned untouched for printing).
    pub fn record(&mut self, m: Measurement) -> &Measurement {
        self.measurements.push(m);
        self.measurements.last().unwrap()
    }

    /// Find a recorded measurement by name.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.measurements.iter().find(|m| m.name == name)
    }

    pub fn to_json(&self) -> Json {
        let meta = json::obj(self.meta.iter().map(|(k, v)| (k.as_str(), v.clone())).collect());
        json::obj(vec![
            ("meta", meta),
            (
                "measurements",
                json::arr(self.measurements.iter().map(Measurement::to_json)),
            ),
        ])
    }

    /// Write the report as pretty JSON.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
    }
}

/// Parse `--json <path>` from a bench binary's argument list
/// (`cargo bench --bench <name> -- --json out.json`). Returns `None`
/// when the flag is absent, so benches stay print-only by default.
pub fn json_path_from_args() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1).cloned())
}

/// Section header printer used by all bench binaries for consistent
/// greppable output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::quick();
        let m = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(black_box(i));
            }
            s
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 5);
        assert!(m.min_ns <= m.mean_ns);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bench::quick();
        let m = b.run_with_items("noop", 1000.0, || 1);
        let t = m.throughput().unwrap();
        assert!(t > 0.0);
        assert!(m.report_line().contains("items/s"));
    }

    #[test]
    fn bench_report_emits_stable_json() {
        let mut r = BenchReport::new();
        r.set_meta("harness", json::s("test"));
        r.set_meta("batch", json::i(1024));
        r.set_meta("harness", json::s("test2")); // later write wins
        let b = Bench::quick();
        r.record(b.run_with_items("noop", 10.0, || 1));
        let j = r.to_json();
        assert_eq!(j.get("meta").get("harness").as_str(), Some("test2"));
        assert_eq!(j.get("meta").get("batch").as_i64(), Some(1024));
        let ms = j.get("measurements").as_arr().unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].get("name").as_str(), Some("noop"));
        assert!(ms[0].get("items_per_s").as_f64().unwrap() > 0.0);
        assert!(r.get("noop").is_some());
        assert!(r.get("nonesuch").is_none());
        // Round-trips through the parser.
        let parsed = json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn alloc_counters_are_monotone_and_callable() {
        // The counting allocator is not installed in unit tests, so
        // the counters stay flat — this asserts the accessors are
        // callable and monotone, not that they observe allocations.
        let g0 = alloc_count();
        let t0 = thread_alloc_count();
        let _v: Vec<u8> = Vec::with_capacity(64);
        assert!(alloc_count() >= g0);
        assert!(thread_alloc_count() >= t0);
    }

    #[test]
    fn os_thread_count_reports_live_threads_where_supported() {
        let Some(before) = os_thread_count() else {
            eprintln!("skipping: /proc/self/status not available");
            return;
        };
        assert!(before >= 1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<()>();
        let t = std::thread::spawn(move || {
            ready_tx.send(()).unwrap();
            rx.recv().unwrap();
        });
        ready_rx.recv().unwrap();
        // The harness runs tests on its own threads, so an exact
        // before/after diff would race other tests; it suffices that
        // the probe sees more than one live thread right now.
        let during = os_thread_count().unwrap();
        assert!(during >= 2, "spawned thread not visible: {during}");
        tx.send(()).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn report_line_formats() {
        let m = Measurement {
            name: "x".into(),
            iters: 3,
            mean_ns: 1_500_000.0,
            p50_ns: 900.0,
            min_ns: 400.0,
            items_per_iter: None,
        };
        let line = m.report_line();
        assert!(line.contains("1.500ms"), "{line}");
        assert!(line.contains("/iter"), "{line}");
        assert!(line.contains("900"), "{line}");
    }
}
