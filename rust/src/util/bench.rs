//! Self-timed micro-benchmark harness (no `criterion` in the offline
//! image). Used by the `rust/benches/*` targets (all `harness = false`).
//!
//! Methodology: warm up for a fixed duration, then run timed batches
//! until a target measurement time elapses; report mean/p50/min over
//! per-iteration times with outlier-robust stats from `util::stats`.

use crate::util::stats::Samples;
use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    /// Optional throughput annotation (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl Measurement {
    /// items/second, if items_per_iter was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.mean_ns * 1e-9))
    }

    pub fn report_line(&self) -> String {
        let tput = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>10.2} Mitems/s", t / 1e6),
            Some(t) => format!("  {:>10.0} items/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12}/iter (p50 {:>12}, min {:>12}, n={}){}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.min_ns),
            self.iters,
            tput
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2}us", ns / 1e3)
    } else {
        format!("{ns:.0}")
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 10,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            min_iters: 5,
        }
    }

    /// Honour `TMFU_BENCH_FAST=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("TMFU_BENCH_FAST").as_deref() == Ok("1") {
            Self::quick()
        } else {
            Self::default()
        }
    }

    /// Time `f`, which performs ONE logical iteration per call. A returned
    /// value is black-boxed to keep the optimizer honest.
    pub fn run<R, F: FnMut() -> R>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Samples::new();
        let mut iters = 0u64;
        let t0 = Instant::now();
        while t0.elapsed() < self.measure || iters < self.min_iters {
            let it = Instant::now();
            black_box(f());
            samples.push(it.elapsed().as_nanos() as f64);
            iters += 1;
        }
        Measurement {
            name: name.to_string(),
            iters,
            mean_ns: samples.mean(),
            p50_ns: samples.percentile(50.0),
            min_ns: samples.min(),
            items_per_iter: None,
        }
    }

    /// Like `run` but annotates throughput.
    pub fn run_with_items<R, F: FnMut() -> R>(
        &self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> Measurement {
        let mut m = self.run(name, f);
        m.items_per_iter = Some(items_per_iter);
        m
    }
}

/// Optimizer barrier (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Section header printer used by all bench binaries for consistent
/// greppable output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench::quick();
        let m = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(black_box(i));
            }
            s
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.iters >= 5);
        assert!(m.min_ns <= m.mean_ns);
    }

    #[test]
    fn throughput_annotation() {
        let b = Bench::quick();
        let m = b.run_with_items("noop", 1000.0, || 1);
        let t = m.throughput().unwrap();
        assert!(t > 0.0);
        assert!(m.report_line().contains("items/s"));
    }

    #[test]
    fn report_line_formats() {
        let m = Measurement {
            name: "x".into(),
            iters: 3,
            mean_ns: 1_500_000.0,
            p50_ns: 900.0,
            min_ns: 400.0,
            items_per_iter: None,
        };
        let line = m.report_line();
        assert!(line.contains("1.500ms"), "{line}");
        assert!(line.contains("/iter"), "{line}");
        assert!(line.contains("900"), "{line}");
    }
}
