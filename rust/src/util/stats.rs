//! Summary statistics for benchmark and latency reporting.

/// Online mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Latency histogram with exact percentiles (stores samples; fine at the
/// scales our serving benches run at).
#[derive(Debug, Clone, Default)]
pub struct Samples {
    xs: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// Percentile via nearest-rank on the sorted samples, p in [0,100].
    pub fn percentile(&mut self, p: f64) -> f64 {
        assert!(!self.xs.is_empty(), "percentile of empty samples");
        assert!((0.0..=100.0).contains(&p));
        self.ensure_sorted();
        if p <= 0.0 {
            return self.xs[0];
        }
        let rank = ((p / 100.0) * self.xs.len() as f64).ceil() as usize;
        self.xs[rank.clamp(1, self.xs.len()) - 1]
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            0.0
        } else {
            self.xs.iter().sum::<f64>() / self.xs.len() as f64
        }
    }

    pub fn min(&mut self) -> f64 {
        self.ensure_sorted();
        self.xs[0]
    }

    pub fn max(&mut self) -> f64 {
        self.ensure_sorted();
        *self.xs.last().unwrap()
    }

    /// Typed distribution summary, `None` when no samples were taken.
    /// The single source for every "n/mean/percentiles/min/max" view —
    /// the rendered one-liner ([`Self::summary`]) and the service
    /// layer's `MetricsSnapshot` both derive from it.
    pub fn summarize(&mut self) -> Option<LatencySummary> {
        if self.xs.is_empty() {
            return None;
        }
        Some(LatencySummary {
            n: self.len(),
            mean: self.mean(),
            p50: self.percentile(50.0),
            p95: self.percentile(95.0),
            p99: self.percentile(99.0),
            min: self.min(),
            max: self.max(),
        })
    }

    /// "p50/p95/p99 mean min max" one-line summary (values in the caller's
    /// unit).
    pub fn summary(&mut self, unit: &str) -> String {
        match self.summarize() {
            Some(s) => s.render(unit),
            None => "no samples".into(),
        }
    }
}

/// Summary of one latency-like distribution (unit decided by the
/// producer; serving metrics use microseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

impl LatencySummary {
    /// The one-line human-readable form.
    pub fn render(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p95={:.3}{u} p99={:.3}{u} min={:.3}{u} max={:.3}{u}",
            self.n,
            self.mean,
            self.p50,
            self.p95,
            self.p99,
            self.min,
            self.max,
            u = unit
        )
    }
}

/// Geometric mean of ratios — used for the "who wins by what factor"
/// summaries in EXPERIMENTS.md.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let logsum: f64 = xs.iter().map(|x| x.ln()).sum();
    (logsum / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_mean_var() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
        assert_eq!(r.count(), 8);
    }

    #[test]
    fn percentiles_nearest_rank() {
        let mut s = Samples::new();
        for x in 1..=100 {
            s.push(x as f64);
        }
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn percentile_single_sample() {
        let mut s = Samples::new();
        s.push(42.0);
        assert_eq!(s.percentile(50.0), 42.0);
        assert_eq!(s.percentile(99.0), 42.0);
    }

    #[test]
    fn geomean_of_constant() {
        assert!((geomean(&[3.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_fields() {
        let mut s = Samples::new();
        s.push(1.0);
        s.push(2.0);
        let line = s.summary("ms");
        assert!(line.contains("p50="));
        assert!(line.contains("n=2"));
    }
}
