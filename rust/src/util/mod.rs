//! Substrate utilities built in-repo (the offline image has no crates.io
//! access — `anyhow` is vendored under `rust/vendor/` and everything else
//! a framework normally pulls from crates.io lives here, with its own
//! tests).

pub mod bench;
pub mod bits;
pub mod cli;
pub mod hmac;
pub mod json;
pub mod prng;
pub mod quickcheck;
pub mod stats;
pub mod sync;
pub mod table;
pub mod threadpool;
