// Static gates (DESIGN.md §12). `unsafe_op_in_unsafe_fn` is a hard
// error: every unsafe operation must sit in an explicit `unsafe {}`
// block with its own SAFETY justification, even inside `unsafe fn`.
// `unreachable_pub` stays at warn here (clippy runs with
// `-D warnings` in `make verify`, which escalates it in the gate)
// so an overlooked site cannot break a plain `cargo build`.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(unreachable_pub)]
// The curated clippy::pedantic subset is scoped where it earns its
// keep: `wire/mod.rs` carries `#![warn(clippy::cast_possible_truncation)]`
// (every `as` in the frame codec must be a checked `try_from`/width
// helper or carry a `cast-ok` annotation), and `tools/source_lint.py`
// enforces the annotation discipline textually in `make verify`.
//! # tmfu-overlay
//!
//! Full-system reproduction of *"An Area-Efficient FPGA Overlay using DSP
//! Block based Time-multiplexed Functional Units"* (2016): a linear
//! pipeline of time-multiplexed, DSP48E1-based functional units plus the
//! scheduling methodology that maps feed-forward data-flow graphs onto it.
//!
//! The crate contains (see `DESIGN.md` for the full inventory):
//!
//! * the **compiler** — kernel language frontend, DFG IR, ASAP stage
//!   scheduler, 32-bit FU instruction / 40-bit context encoding
//!   ([`frontend`], [`dfg`], [`sched`], [`isa`]);
//! * the **cycle-accurate overlay simulator** — DSP48E1 model, FU
//!   microarchitecture, linear pipeline, FIFOs, multi-pipeline overlay
//!   ([`arch`], [`sim`]);
//! * **resource/frequency models** calibrated to the paper's synthesis
//!   results, plus the SCFU-SCN / Vivado-HLS / related-work baselines
//!   ([`resources`], [`baseline`]);
//! * the **execution backend layer** — one [`exec::Backend`] contract
//!   with four interchangeable substrates: the DFG interpreter, the
//!   tape-compiled turbo executor (flat op tapes, lane-chunked,
//!   allocation-free steady state), the cycle-accurate overlay
//!   simulator (with modeled context switching), and the PJRT engine
//!   over the AOT-compiled (JAX + Pallas) kernels ([`exec`],
//!   [`runtime`]);
//! * the **service API** — the public, typed client/service surface:
//!   [`service::OverlayService`] (builder-configured: backend kind,
//!   pipelines, max batch, bounded admission queues) hands out
//!   `Clone + Send` [`service::KernelHandle`] sessions with
//!   pre-resolved kernel ids; calls return structured
//!   [`service::ServiceError`]s and metrics come back as a typed,
//!   JSON-serializable [`service::MetricsSnapshot`]. The engine behind
//!   it — backend-generic fabric workers over a shared compiled-kernel
//!   registry, dispatching flat [`exec::FlatBatch`] batches from
//!   [`exec::KernelId`]-indexed bounded queues — is crate-private.
//!   Runs the full serving stack with zero artifacts via
//!   `tmfu serve --backend sim` (or `turbo`) ([`service`]);
//! * the **wire protocol** — a versioned, length-prefixed binary
//!   protocol over TCP/Unix sockets ([`wire`], DESIGN.md §9,
//!   `docs/PROTOCOL.md`): `tmfu listen` serves an `OverlayService`
//!   to other processes, and the thin [`client::OverlayClient`] /
//!   [`client::RemoteKernel`] mirror the in-process sessions method
//!   for method, with every [`service::ServiceError`] variant
//!   round-tripped bit-exactly as typed error frames;
//! * the **router** — a fault-tolerant front for replicated backends
//!   ([`router`], DESIGN.md §11): `tmfu router` speaks the wire
//!   protocol on both sides, health-checks its replicas, retries
//!   idempotent calls with capped backoff on replica failure, and
//!   drains gracefully, so a `kill -9`ed backend degrades to the
//!   survivors instead of failing the burst;
//! * the **static verifier** — per-kernel IR checking over the whole
//!   compiled pipeline ([`verify`], DESIGN.md §12): DFG
//!   well-formedness, schedule legality, tape slot safety (proving
//!   the SIMD interpreter's bounds assumptions) and ISA-context
//!   consistency, gating `OverlayService::builder()` (typed
//!   `InvalidKernel` rejection) and the committed artifacts
//!   (`tmfu verify`), with a mutation harness keeping the pass
//!   honest;
//! * **reporting** — regeneration of every table/figure in the paper
//!   ([`report`], `rust/benches/`).

pub mod arch;
pub mod baseline;
pub mod bench_suite;
pub mod client;
pub(crate) mod coordinator;
pub mod dfg;
pub mod exec;
pub mod frontend;
pub mod isa;
pub mod report;
pub mod resources;
pub mod router;
pub mod runtime;
pub mod sched;
pub mod service;
pub mod sim;
pub mod util;
pub mod verify;
pub mod wire;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
