//! # tmfu-overlay
//!
//! Full-system reproduction of *"An Area-Efficient FPGA Overlay using DSP
//! Block based Time-multiplexed Functional Units"* (2016): a linear
//! pipeline of time-multiplexed, DSP48E1-based functional units plus the
//! scheduling methodology that maps feed-forward data-flow graphs onto it.
//!
//! The crate contains (see `DESIGN.md` for the full inventory):
//!
//! * the **compiler** — kernel language frontend, DFG IR, ASAP stage
//!   scheduler, 32-bit FU instruction / 40-bit context encoding
//!   ([`frontend`], [`dfg`], [`sched`], [`isa`]);
//! * the **cycle-accurate overlay simulator** — DSP48E1 model, FU
//!   microarchitecture, linear pipeline, FIFOs, multi-pipeline overlay
//!   ([`arch`], [`sim`]);
//! * **resource/frequency models** calibrated to the paper's synthesis
//!   results, plus the SCFU-SCN / Vivado-HLS / related-work baselines
//!   ([`resources`], [`baseline`]);
//! * the **runtime** — PJRT loader executing the AOT-compiled (JAX +
//!   Pallas) kernels on the data path, and the serving coordinator
//!   ([`runtime`], [`coordinator`]);
//! * **reporting** — regeneration of every table/figure in the paper
//!   ([`report`], `rust/benches/`).

pub mod arch;
pub mod baseline;
pub mod bench_suite;
pub mod coordinator;
pub mod dfg;
pub mod frontend;
pub mod isa;
pub mod report;
pub mod resources;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
