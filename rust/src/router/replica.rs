//! One managed downstream backend: its live connection (when up), a
//! resolved-kernel session cache, and the monitor loop that probes
//! health and reconnects with jittered capped-exponential backoff.
//!
//! A replica's link moves between two states:
//!
//! * **down** — no connection. The monitor retries
//!   [`crate::client::OverlayClient::connect`] on a [`Backoff`]
//!   schedule; every successful connect bumps the link **epoch**.
//! * **up** — a live [`OverlayClient`] plus the [`RemoteKernel`]
//!   sessions resolved through it so far. The monitor sends a `Health`
//!   probe every `probe_interval`; a failed probe (or a `draining`
//!   report) takes the link down.
//!
//! The data path participates in health too (*passive* detection): a
//! forwarder that sees a transport-shaped failure calls
//! [`Replica::mark_down`] with the epoch it dispatched under, so the
//! table reflects a dead backend within one failed call instead of one
//! probe period. The epoch guard makes stale reports harmless — a
//! failure observed on epoch N cannot shoot down the epoch N+1 link
//! the monitor already rebuilt.

use crate::client::{Backoff, ClientBuilder, OverlayClient, RemoteKernel};
use crate::service::ServiceError;
use crate::util::sync::LockExt;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// EWMA weight on the old reply-latency value (matches the engine's
/// per-kernel service-rate estimator): `new = old*0.8 + sample*0.2`.
const LATENCY_ALPHA: f64 = 0.8;

/// Timing knobs for a replica's monitor loop (copied out of
/// `RouterConfig` so this module does not depend on the router's).
#[derive(Debug, Clone)]
pub struct ReplicaTuning {
    /// Health-probe period while the link is up.
    pub probe_interval: Duration,
    /// Reconnect backoff: first delay.
    pub backoff_base: Duration,
    /// Reconnect backoff: delay ceiling.
    pub backoff_cap: Duration,
    /// TCP connect timeout for each (re)connect attempt.
    pub connect_timeout: Duration,
    /// Client read-silence bound (see `ClientBuilder::read_timeout`).
    pub read_timeout: Duration,
    /// Tenant the router authenticates as on this downstream link
    /// (each reconnect signs a fresh-nonce token). `None` dials
    /// anonymously — fine against auth-off backends.
    pub tenant: Option<String>,
    /// Shared secret for `tenant`.
    pub secret: Option<Vec<u8>>,
}

/// A live link: the client plus every kernel session resolved so far.
struct LinkUp {
    client: Arc<OverlayClient>,
    kernels: HashMap<String, RemoteKernel>,
}

struct Link {
    up: Option<LinkUp>,
    /// Bumped on every successful (re)connect. Data-path failure
    /// reports carry the epoch they dispatched under; mismatches are
    /// ignored.
    epoch: u64,
}

/// One managed downstream backend (see module docs).
pub struct Replica {
    addr: String,
    tuning: ReplicaTuning,
    link: Mutex<Link>,
    /// Wakes the monitor out of a probe/backoff sleep early (shutdown,
    /// or a data-path `mark_down` asking for a prompt reconnect).
    kick: Condvar,
    stopping: AtomicBool,
    /// Reply-latency EWMA in microseconds (f64 bits; 0.0 = no sample
    /// yet), fed by the forwarders on every successful reply. The
    /// router's retry gate reads it to decide whether a remaining
    /// deadline budget can still cover one more dispatch.
    latency_us: AtomicU64,
}

impl Replica {
    pub fn new(addr: String, tuning: ReplicaTuning) -> Arc<Replica> {
        Arc::new(Replica {
            addr,
            tuning,
            link: Mutex::new(Link { up: None, epoch: 0 }),
            kick: Condvar::new(),
            stopping: AtomicBool::new(false),
            latency_us: AtomicU64::new(0.0f64.to_bits()),
        })
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn is_up(&self) -> bool {
        self.link.lock_unpoisoned().up.is_some()
    }

    /// Current link epoch (for metrics; counts successful connects).
    pub fn epoch(&self) -> u64 {
        self.link.lock_unpoisoned().epoch
    }

    /// Resolve a kernel session on this replica, caching it for the
    /// link's lifetime. `Disconnected` while the link is down;
    /// `UnknownKernel` passes through (this backend does not own the
    /// kernel — the table tries the next one). The resolve roundtrip
    /// runs outside the link lock; a transport failure during it takes
    /// the link down.
    pub fn kernel(&self, name: &str) -> Result<(RemoteKernel, u64), ServiceError> {
        let (client, epoch) = {
            let st = self.link.lock_unpoisoned();
            match &st.up {
                Some(up) => {
                    if let Some(k) = up.kernels.get(name) {
                        return Ok((k.clone(), st.epoch));
                    }
                    (Arc::clone(&up.client), st.epoch)
                }
                None => {
                    return Err(ServiceError::Disconnected {
                        kernel: name.to_string(),
                    })
                }
            }
        };
        match client.kernel(name) {
            Ok(k) => {
                let mut st = self.link.lock_unpoisoned();
                if st.epoch == epoch {
                    if let Some(up) = st.up.as_mut() {
                        up.kernels.insert(name.to_string(), k.clone());
                    }
                }
                Ok((k, epoch))
            }
            Err(e @ ServiceError::UnknownKernel(_)) => Err(e),
            Err(e) => {
                // Resolution failed for transport-ish reasons: the
                // link is suspect. Let the monitor rebuild it.
                self.mark_down(epoch);
                Err(e)
            }
        }
    }

    /// Fold one observed reply latency (microseconds) into the EWMA.
    /// Junk samples (non-finite or non-positive) are ignored; the
    /// first real sample is adopted whole. The load/blend/store is
    /// racy by design — a lost update skews the estimate by one
    /// sample, and the estimate is advisory.
    pub fn record_latency(&self, us: f64) {
        if !us.is_finite() || us <= 0.0 {
            return;
        }
        // relaxed-ok: advisory estimator, see above.
        let old = f64::from_bits(self.latency_us.load(Ordering::Relaxed));
        let new = if old == 0.0 {
            us
        } else {
            old * LATENCY_ALPHA + us * (1.0 - LATENCY_ALPHA)
        };
        // relaxed-ok: advisory estimator, see above.
        self.latency_us.store(new.to_bits(), Ordering::Relaxed);
    }

    /// Current reply-latency EWMA in microseconds (0.0 = no sample).
    pub fn latency_us(&self) -> f64 {
        // relaxed-ok: advisory estimator.
        f64::from_bits(self.latency_us.load(Ordering::Relaxed))
    }

    /// Data-path health report: a call dispatched under `epoch` failed
    /// in a transport-shaped way. Ignored if the link was already
    /// rebuilt (epoch mismatch) or is already down.
    pub fn mark_down(&self, epoch: u64) {
        let mut st = self.link.lock_unpoisoned();
        if st.epoch != epoch || st.up.is_none() {
            return;
        }
        // Dropping the client closes the socket; its outstanding
        // pendings settle as Disconnected, which is exactly what
        // retry-on-another-replica expects.
        st.up = None;
        drop(st);
        // Prompt the monitor: reconnect now, not at the next tick.
        self.kick.notify_all();
    }

    /// Stop the monitor loop (idempotent); the link is torn down.
    pub fn stop(&self) {
        self.stopping.store(true, Ordering::SeqCst);
        self.link.lock_unpoisoned().up = None;
        self.kick.notify_all();
    }

    fn stopping(&self) -> bool {
        self.stopping.load(Ordering::SeqCst)
    }

    /// Interruptible sleep: returns early on [`Self::stop`] or
    /// [`Self::mark_down`].
    fn doze(&self, d: Duration) {
        let st = self.link.lock_unpoisoned();
        let _ = self.kick.wait_timeout(st, d).unwrap();
    }

    fn install(&self, client: OverlayClient) {
        let mut st = self.link.lock_unpoisoned();
        st.epoch += 1;
        st.up = Some(LinkUp {
            client: Arc::new(client),
            kernels: HashMap::new(),
        });
    }

    /// One monitor step; split out of [`monitor`] for testability.
    /// Returns the duration to doze before the next step.
    fn step(&self, backoff: &mut Backoff) -> Duration {
        let probe = {
            let st = self.link.lock_unpoisoned();
            st.up
                .as_ref()
                .map(|up| (Arc::clone(&up.client), st.epoch))
        };
        match probe {
            Some((client, epoch)) => {
                // v1 backends cannot answer Health; keep the link on
                // passive detection alone rather than probing it dead.
                if client.version() >= 2 {
                    match client.health() {
                        Ok(report) if !report.draining => {}
                        // Draining or unreachable: take it out of the
                        // rotation (a draining backend finishes its
                        // in-flight work but must get nothing new).
                        _ => {
                            self.mark_down(epoch);
                            return Duration::ZERO;
                        }
                    }
                }
                backoff.reset();
                self.tuning.probe_interval
            }
            None => {
                let mut builder = ClientBuilder::new()
                    .connect_timeout(Some(self.tuning.connect_timeout))
                    .read_timeout(Some(self.tuning.read_timeout));
                if let Some(tenant) = &self.tuning.tenant {
                    builder = builder.tenant(tenant);
                }
                if let Some(secret) = &self.tuning.secret {
                    builder = builder.secret(secret);
                }
                let dial = builder.connect(&self.addr);
                match dial {
                    Ok(client) => {
                        self.install(client);
                        backoff.reset();
                        Duration::ZERO
                    }
                    Err(_) => backoff.next_delay(),
                }
            }
        }
    }
}

/// Seed the reconnect jitter from the address so a fleet of replicas
/// (and a restarted router) spread their retries deterministically but
/// differently per backend.
fn jitter_seed(addr: &str) -> u64 {
    // FNV-1a, enough to decorrelate a handful of addresses.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in addr.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// The monitor loop body: run on a dedicated thread per replica until
/// [`Replica::stop`].
pub fn monitor(replica: &Replica) {
    let mut backoff = Backoff::new(
        replica.tuning.backoff_base,
        replica.tuning.backoff_cap,
        jitter_seed(&replica.addr),
    );
    while !replica.stopping() {
        let nap = replica.step(&mut backoff);
        if replica.stopping() {
            break;
        }
        if !nap.is_zero() {
            replica.doze(nap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuning() -> ReplicaTuning {
        ReplicaTuning {
            probe_interval: Duration::from_millis(50),
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(40),
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(500),
            tenant: None,
            secret: None,
        }
    }

    #[test]
    fn down_replica_answers_disconnected_and_backoff_grows() {
        // Port 9 (discard) on a host nobody binds: connect fails fast
        // on loopback with ECONNREFUSED.
        let r = Replica::new("127.0.0.1:9".to_string(), tuning());
        assert!(!r.is_up());
        let err = r.kernel("fir").unwrap_err();
        assert!(matches!(err, ServiceError::Disconnected { .. }));
        let mut backoff = Backoff::new(
            Duration::from_millis(5),
            Duration::from_millis(40),
            jitter_seed(r.addr()),
        );
        // A failed connect step returns a backoff delay, not a probe
        // interval.
        let nap = r.step(&mut backoff);
        assert!(!nap.is_zero());
        assert!(nap <= Duration::from_millis(40));
        assert!(!r.is_up());
    }

    #[test]
    fn stale_epoch_cannot_down_a_rebuilt_link() {
        let r = Replica::new("127.0.0.1:9".to_string(), tuning());
        // No link at all: mark_down of any epoch is a no-op.
        r.mark_down(0);
        r.mark_down(7);
        assert_eq!(r.epoch(), 0);
        assert!(!r.is_up());
    }

    #[test]
    fn jitter_seeds_differ_per_address() {
        assert_ne!(jitter_seed("127.0.0.1:7701"), jitter_seed("127.0.0.1:7702"));
    }

    #[test]
    fn latency_ewma_blends_and_ignores_junk() {
        let r = Replica::new("127.0.0.1:9".to_string(), tuning());
        assert_eq!(r.latency_us(), 0.0, "no sample yet");
        r.record_latency(10.0);
        assert_eq!(r.latency_us(), 10.0, "first sample adopted whole");
        r.record_latency(20.0);
        assert!((r.latency_us() - 12.0).abs() < 1e-9, "0.8*10 + 0.2*20");
        r.record_latency(f64::NAN);
        r.record_latency(-5.0);
        r.record_latency(0.0);
        assert!((r.latency_us() - 12.0).abs() < 1e-9, "junk ignored");
    }
}
