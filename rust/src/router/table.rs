//! The routing table and the router's metrics ledger.
//!
//! [`RoutingTable::pick`] is the one routing decision in the system:
//! given a kernel name, walk the replicas round-robin from a rotating
//! cursor and return the first healthy session that owns the kernel.
//! Replicas that are down answer `Disconnected` and are skipped; a
//! replica that is up but does not own the kernel answers
//! `UnknownKernel`. Only when *no* replica is reachable does the
//! caller get the typed [`ServiceError::Unavailable`] — the
//! router-level "try again later" signal — while "every reachable
//! replica disowns it" stays `UnknownKernel`, the request-is-wrong
//! signal.
//!
//! [`RouterMetrics`] keeps the ledger the chaos gate asserts on:
//! `admitted == completed + failed + cancelled` once traffic
//! quiesces, with `retries` counting transparent re-dispatches (a
//! retried call is still one admitted request) and `cancelled`
//! counting requests the upstream peer withdrew with a `Cancel`
//! frame before they settled.

use super::replica::Replica;
use crate::client::RemoteKernel;
use crate::service::ServiceError;
use crate::util::json::{self, Json};
use crate::util::sync::LockExt;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Round-robin selection over the managed replicas.
pub struct RoutingTable {
    replicas: Vec<Arc<Replica>>,
    cursor: AtomicUsize,
}

impl RoutingTable {
    pub fn new(replicas: Vec<Arc<Replica>>) -> RoutingTable {
        RoutingTable {
            replicas,
            cursor: AtomicUsize::new(0),
        }
    }

    pub fn replicas(&self) -> &[Arc<Replica>] {
        &self.replicas
    }

    pub fn replica(&self, idx: usize) -> &Arc<Replica> {
        &self.replicas[idx]
    }

    /// Route one call: the first healthy replica (round-robin from a
    /// rotating start) that owns `kernel`. Returns the session, the
    /// replica index, and the link epoch the session belongs to (for
    /// the data path's `mark_down` reports).
    pub fn pick(&self, kernel: &str) -> Result<(RemoteKernel, usize, u64), ServiceError> {
        let n = self.replicas.len();
        // relaxed-ok: rotation cursor; a stale start only shifts the
        // round-robin origin, never correctness.
        let start = self.cursor.fetch_add(1, Ordering::Relaxed);
        let mut saw_unknown = false;
        for i in 0..n {
            let idx = (start + i) % n;
            match self.replicas[idx].kernel(kernel) {
                Ok((k, epoch)) => return Ok((k, idx, epoch)),
                Err(ServiceError::UnknownKernel(_)) => saw_unknown = true,
                // Down, draining, or failed mid-resolve: try the next.
                Err(_) => {}
            }
        }
        if saw_unknown {
            Err(ServiceError::UnknownKernel(kernel.to_string()))
        } else {
            Err(ServiceError::Unavailable {
                kernel: kernel.to_string(),
            })
        }
    }

    /// The fastest up replica's reply-latency EWMA, in microseconds;
    /// `0.0` when no up replica has a sample yet. The retry gate uses
    /// this as the cheapest plausible cost of one more dispatch: a
    /// remaining deadline budget below it means the retry is doomed.
    pub fn min_latency_us(&self) -> f64 {
        self.replicas
            .iter()
            .filter(|r| r.is_up())
            .map(|r| r.latency_us())
            .filter(|&l| l > 0.0)
            .fold(0.0f64, |best, l| if best == 0.0 { l } else { best.min(l) })
    }
}

/// The router's request ledger plus retry counter. Updated by the
/// upstream readers (admitted) and reactors (completed / failed /
/// retries); exposed as JSON through `GetMetrics` and `Router::
/// metrics_json`.
#[derive(Debug, Default)]
pub struct RouterMetrics {
    admitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    cancelled: AtomicU64,
    retries: AtomicU64,
    /// Requests currently in flight per tenant label (from the
    /// upstream Hello token; anonymous connections count under
    /// "default"). A BTreeMap so the JSON keys come out sorted.
    tenant_inflight: Mutex<BTreeMap<String, u64>>,
}

impl RouterMetrics {
    pub fn admit(&self) {
        self.admitted.fetch_add(1, Ordering::SeqCst);
    }

    /// One request admitted for `tenant`: bump its inflight gauge.
    pub fn tenant_admit(&self, tenant: &str) {
        let mut map = self.tenant_inflight.lock_unpoisoned();
        match map.get_mut(tenant) {
            Some(n) => *n += 1,
            None => {
                map.insert(tenant.to_string(), 1);
            }
        }
    }

    /// One of `tenant`'s requests settled (reply or typed error):
    /// drop its inflight gauge. The zero entry stays — "this tenant
    /// has been seen" is useful in the metrics JSON.
    pub fn tenant_settle(&self, tenant: &str) {
        if let Some(n) = self.tenant_inflight.lock_unpoisoned().get_mut(tenant) {
            *n = n.saturating_sub(1);
        }
    }

    /// Current inflight count for `tenant` (0 if never seen).
    pub fn tenant_inflight(&self, tenant: &str) -> u64 {
        self.tenant_inflight
            .lock_unpoisoned()
            .get(tenant)
            .copied()
            .unwrap_or(0)
    }

    pub fn complete(&self) {
        self.completed.fetch_add(1, Ordering::SeqCst);
    }

    pub fn fail(&self, n: u64) {
        self.failed.fetch_add(n, Ordering::SeqCst);
    }

    /// One admitted request withdrawn by an upstream `Cancel` before
    /// it settled (the third term of the ledger invariant).
    pub fn cancel(&self) {
        self.cancelled.fetch_add(1, Ordering::SeqCst);
    }

    pub fn retry(&self) {
        self.retries.fetch_add(1, Ordering::SeqCst);
    }

    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::SeqCst)
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::SeqCst)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::SeqCst)
    }

    pub fn cancelled(&self) -> u64 {
        self.cancelled.load(Ordering::SeqCst)
    }

    pub fn retries(&self) -> u64 {
        self.retries.load(Ordering::SeqCst)
    }

    /// The ledger plus per-backend link state, as the JSON object
    /// served for `GetMetrics` on the router's upstream side.
    pub fn to_json(&self, table: &RoutingTable) -> Json {
        let backends = table.replicas().iter().map(|r| {
            json::obj(vec![
                ("addr", json::s(r.addr())),
                ("up", Json::Bool(r.is_up())),
                ("epoch", json::i(r.epoch() as i64)),
            ])
        });
        let tenants: std::collections::BTreeMap<String, Json> = self
            .tenant_inflight
            .lock_unpoisoned()
            .iter()
            .map(|(name, n)| {
                // cast-ok: an inflight gauge is bounded far below i64::MAX.
                (name.clone(), json::i(*n as i64))
            })
            .collect();
        json::obj(vec![
            ("role", json::s("router")),
            ("admitted", json::i(self.admitted() as i64)),
            ("completed", json::i(self.completed() as i64)),
            ("failed", json::i(self.failed() as i64)),
            ("cancelled", json::i(self.cancelled() as i64)),
            ("retries", json::i(self.retries() as i64)),
            ("tenants", Json::Obj(tenants)),
            ("backends", json::arr(backends)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::super::replica::ReplicaTuning;
    use super::*;
    use std::time::Duration;

    fn tuning() -> ReplicaTuning {
        ReplicaTuning {
            probe_interval: Duration::from_millis(50),
            backoff_base: Duration::from_millis(5),
            backoff_cap: Duration::from_millis(40),
            connect_timeout: Duration::from_millis(200),
            read_timeout: Duration::from_millis(500),
            tenant: None,
            secret: None,
        }
    }

    #[test]
    fn all_replicas_down_is_unavailable() {
        let table = RoutingTable::new(vec![
            Replica::new("127.0.0.1:9".to_string(), tuning()),
            Replica::new("127.0.0.1:10".to_string(), tuning()),
        ]);
        let err = table.pick("fir").unwrap_err();
        assert!(
            matches!(err, ServiceError::Unavailable { ref kernel } if kernel == "fir"),
            "got {err}"
        );
    }

    #[test]
    fn ledger_counts_and_json_shape() {
        let m = RouterMetrics::default();
        m.admit();
        m.admit();
        m.admit();
        m.complete();
        m.fail(1);
        m.cancel();
        m.retry();
        assert_eq!(m.admitted(), m.completed() + m.failed() + m.cancelled());
        let table = RoutingTable::new(vec![Replica::new("127.0.0.1:9".to_string(), tuning())]);
        let j = m.to_json(&table);
        assert_eq!(j.get("admitted").as_i64(), Some(3));
        assert_eq!(j.get("cancelled").as_i64(), Some(1));
        assert_eq!(j.get("retries").as_i64(), Some(1));
        assert_eq!(j.get("backends").as_arr().map(<[Json]>::len), Some(1));
        assert_eq!(j.get("backends").at(0).get("up").as_bool(), Some(false));
    }

    #[test]
    fn tenant_inflight_gauge_tracks_admits_and_settles() {
        let m = RouterMetrics::default();
        assert_eq!(m.tenant_inflight("acme"), 0);
        m.tenant_admit("acme");
        m.tenant_admit("acme");
        m.tenant_admit("default");
        assert_eq!(m.tenant_inflight("acme"), 2);
        m.tenant_settle("acme");
        assert_eq!(m.tenant_inflight("acme"), 1);
        // Settling an unknown tenant (or below zero) never underflows.
        m.tenant_settle("nonesuch");
        m.tenant_settle("default");
        m.tenant_settle("default");
        assert_eq!(m.tenant_inflight("default"), 0);
        let table = RoutingTable::new(vec![Replica::new("127.0.0.1:9".to_string(), tuning())]);
        let j = m.to_json(&table);
        assert_eq!(j.get("tenants").get("acme").as_i64(), Some(1));
        // A settled tenant stays visible at zero.
        assert_eq!(j.get("tenants").get("default").as_i64(), Some(0));
    }
}
