//! Fault-tolerant front for a fleet of overlay backends.
//!
//! The router speaks the length-prefixed wire protocol on **both**
//! sides: upstream it accepts connections exactly like
//! [`WireServer`](crate::wire::server::WireServer) (same handshake,
//! same frame set, same drain semantics), downstream it holds one
//! [`OverlayClient`](crate::client::OverlayClient) per backend,
//! managed by [`replica::Replica`] monitors that probe health and
//! reconnect with jittered backoff.
//!
//! ```text
//!              upstream (server side)        downstream (client side)
//!   client ──▶ ┌───────────────────────┐ ──▶ backend A (tmfu listen)
//!   client ──▶ │  router: table + retry│ ──▶ backend B (tmfu listen)
//!   client ──▶ └───────────────────────┘ ──▶ backend C (tmfu listen)
//! ```
//!
//! Every upstream `Call`/`CallBatch` becomes a forward entry: it is
//! dispatched to a healthy replica picked round-robin by the
//! [`table::RoutingTable`], and on a **retryable** failure (see
//! [`retryable`]) it is transparently re-dispatched — capped
//! exponential backoff between attempts, a per-call deadline, and a
//! bounded attempt budget. Overlay kernels are pure functions of their
//! inputs, so re-running a call on another replica is safe
//! (idempotent); deterministic failures (shape mismatch, unknown
//! kernel) are *not* retried and fail fast with their typed error.
//!
//! The ledger invariant the chaos tests assert: every admitted request
//! settles exactly once — a bit-exact `Reply`, a typed `Error`, or an
//! upstream `Cancel` withdrawal — so `admitted == completed + failed +
//! cancelled` on [`table::RouterMetrics`] once traffic quiesces, even
//! when a backend is `kill -9`ed mid-burst.
//!
//! Deadlines propagate end to end: a v2 `Call` carrying `deadline_us`
//! caps the per-call deadline at `min(budget, call_deadline)`, every
//! downstream dispatch forwards the *remaining* budget (decremented by
//! the time already burned at this hop), and a retry is only armed
//! when the remaining budget can still cover the fastest replica's
//! reply-latency EWMA — otherwise the call settles typed immediately
//! instead of burning the budget on a dispatch doomed to expire. An
//! upstream `Cancel` cancels the downstream dispatch in turn, so the
//! withdrawal reaches the backend's queue.

pub mod replica;
pub mod table;

use crate::client::{Backoff, RemotePending, RemotePendingBatch};
use crate::coordinator::completion::Wake;
use crate::exec::FlatBatch;
use crate::service::ServiceError;
use crate::util::json::Json;
use crate::wire::server::{
    bind_listener, deadline_requires_v2, frame_name, malformed, sigterm_drain_requested,
    unknown_kernel, ServerCtl,
};
use crate::util::sync::LockExt;
use crate::wire::{
    read_frame_patient, write_frame, Frame, ListenAddr, PatientRead, WireError, WireStream,
    HEALTH_DRAINING, HEALTH_SERVING, WIRE_VERSION_MAX, WIRE_VERSION_MIN,
};
use anyhow::{Context, Result};
use replica::{monitor, Replica, ReplicaTuning};
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{self, BufReader, BufWriter, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};
use table::{RouterMetrics, RoutingTable};

/// Everything tunable about a router. `RouterConfig::new(backends)`
/// gives the production defaults; tests shrink the durations.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Backend addresses (`host:port` or `unix:/path`), one replica
    /// each.
    pub backends: Vec<String>,
    /// Health-probe period per backend while its link is up.
    pub probe_interval: Duration,
    /// Per-call deadline: an admitted request settles (reply or typed
    /// error) within this bound, no matter how many retries it takes.
    pub call_deadline: Duration,
    /// Retry budget: re-dispatches allowed after the first attempt.
    pub max_retries: u32,
    /// First retry/reconnect backoff delay.
    pub backoff_base: Duration,
    /// Retry/reconnect backoff ceiling.
    pub backoff_cap: Duration,
    /// TCP connect timeout for each downstream (re)connect.
    pub connect_timeout: Duration,
    /// Downstream client read-silence bound.
    pub read_timeout: Duration,
    /// Tenant the router authenticates *as* on every downstream
    /// connection (auth-required backends). Upstream tokens are
    /// attribution labels only — each downstream Hello needs a fresh
    /// nonce, so the router signs with its own credentials rather
    /// than replaying a client's.
    pub tenant: Option<String>,
    /// Shared secret for [`Self::tenant`].
    pub secret: Option<Vec<u8>>,
}

impl RouterConfig {
    pub fn new(backends: Vec<String>) -> RouterConfig {
        RouterConfig {
            backends,
            probe_interval: Duration::from_secs(2),
            call_deadline: Duration::from_secs(30),
            max_retries: 4,
            backoff_base: Duration::from_millis(50),
            backoff_cap: Duration::from_secs(2),
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(30),
            tenant: None,
            secret: None,
        }
    }

    fn tuning(&self) -> ReplicaTuning {
        ReplicaTuning {
            probe_interval: self.probe_interval,
            backoff_base: self.backoff_base,
            backoff_cap: self.backoff_cap,
            connect_timeout: self.connect_timeout,
            read_timeout: self.read_timeout,
            tenant: self.tenant.clone(),
            secret: self.secret.clone(),
        }
    }
}

/// Is this failure worth re-dispatching to another replica? Kernels
/// are pure, so any call may be safely re-run; what this classifies is
/// whether the failure is *environmental* (a different replica, or the
/// same one a moment later, may succeed) or *deterministic* (every
/// replica gives the same answer, so retrying only burns the
/// deadline). `Backend` errors count only when the wire layer produced
/// them — an engine-side backend fault is deterministic.
pub fn retryable(e: &ServiceError) -> bool {
    match e {
        ServiceError::Disconnected { .. }
        | ServiceError::Unavailable { .. }
        | ServiceError::ShutDown
        | ServiceError::Rejected { .. } => true,
        ServiceError::Backend { backend, .. } => backend == "wire",
        _ => false,
    }
}

/// A transport-shaped failure also tells us the *link* it happened on
/// is suspect — worth a passive `mark_down` so the table stops routing
/// there before the next health probe. (`Unavailable`/`Rejected` are
/// retryable but say nothing about the link.)
fn transport_shaped(e: &ServiceError) -> bool {
    match e {
        ServiceError::Disconnected { .. } => true,
        ServiceError::Backend { backend, .. } => backend == "wire",
        _ => false,
    }
}

/// State shared by every upstream connection of one router.
struct RouterShared {
    table: RoutingTable,
    metrics: RouterMetrics,
    cfg: RouterConfig,
    /// The router's own kernel-id namespace. Upstream `Resolve`
    /// interns the name here and hands back the index; `Call` frames
    /// index it to get the name back. Downstream dense ids are
    /// per-backend (registries may differ) and never leak upstream.
    names: Mutex<Vec<String>>,
}

impl RouterShared {
    fn intern(&self, name: &str) -> u32 {
        let mut names = self.names.lock_unpoisoned();
        if let Some(i) = names.iter().position(|n| n == name) {
            return i as u32;
        }
        names.push(name.to_string());
        (names.len() - 1) as u32
    }

    fn name_of(&self, rid: u32) -> Option<String> {
        self.names.lock_unpoisoned().get(rid as usize).cloned()
    }
}

/// A running router: upstream acceptor + per-connection forwarders +
/// one monitor thread per backend. Lifecycle mirrors
/// [`WireServer`](crate::wire::server::WireServer): [`Router::wait`]
/// for the foreground
/// drain-on-signal mode, [`Router::shutdown`] for tests. Dropping the
/// value does not stop it.
pub struct Router {
    addr: ListenAddr,
    unix_path: Option<std::path::PathBuf>,
    stop: Arc<AtomicBool>,
    ctl: Arc<ServerCtl>,
    shared: Arc<RouterShared>,
    acceptor: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    streams: Arc<Mutex<HashMap<u64, WireStream>>>,
    monitors: Vec<thread::JoinHandle<()>>,
}

impl Router {
    /// Spawn the replica monitors, bind the upstream listener, and
    /// start accepting. TCP port 0 resolves to an ephemeral port (see
    /// [`Router::addr`]).
    pub fn start(cfg: RouterConfig, addr: &ListenAddr) -> Result<Router> {
        anyhow::ensure!(
            !cfg.backends.is_empty(),
            "router needs at least one backend address"
        );
        let tuning = cfg.tuning();
        let replicas: Vec<Arc<Replica>> = cfg
            .backends
            .iter()
            .map(|a| Replica::new(a.clone(), tuning.clone()))
            .collect();
        let mut monitors = Vec::with_capacity(replicas.len());
        for (i, r) in replicas.iter().enumerate() {
            let r = Arc::clone(r);
            let handle = thread::Builder::new()
                .name(format!("router-probe-{i}"))
                .spawn(move || monitor(&r))
                .context("spawn replica monitor")?;
            monitors.push(handle);
        }
        let shared = Arc::new(RouterShared {
            table: RoutingTable::new(replicas),
            metrics: RouterMetrics::default(),
            cfg,
            names: Mutex::new(Vec::new()),
        });
        let (listener, resolved, unix_path) = bind_listener(addr)?;
        let ctl = ServerCtl::new();
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let streams: Arc<Mutex<HashMap<u64, WireStream>>> = Arc::new(Mutex::new(HashMap::new()));
        let acceptor = {
            let stop = Arc::clone(&stop);
            let conns = Arc::clone(&conns);
            let streams = Arc::clone(&streams);
            let ctl = Arc::clone(&ctl);
            let shared = Arc::clone(&shared);
            thread::Builder::new()
                .name("router-accept".to_string())
                .spawn(move || {
                    let mut accepted = 0u64;
                    loop {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        if sigterm_drain_requested() {
                            ctl.drain();
                        }
                        if ctl.is_draining() {
                            break;
                        }
                        let stream = match listener.accept() {
                            Ok(s) => s,
                            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                                thread::sleep(Duration::from_millis(5));
                                continue;
                            }
                            // Transient accept failures must not spin.
                            Err(_) => {
                                thread::sleep(Duration::from_millis(10));
                                continue;
                            }
                        };
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        accepted += 1;
                        let conn_id = accepted;
                        let control = match stream.try_clone() {
                            Ok(c) => c,
                            Err(_) => continue,
                        };
                        streams.lock_unpoisoned().insert(conn_id, control);
                        let conn_shared = Arc::clone(&shared);
                        let conn_streams = Arc::clone(&streams);
                        let conn_ctl = Arc::clone(&ctl);
                        let spawned = thread::Builder::new()
                            .name(format!("router-conn-{conn_id}"))
                            .spawn(move || {
                                forward_connection(conn_shared, stream, conn_ctl);
                                conn_streams.lock_unpoisoned().remove(&conn_id);
                            });
                        match spawned {
                            Ok(handle) => {
                                let mut cs = conns.lock_unpoisoned();
                                cs.retain(|h| !h.is_finished());
                                cs.push(handle);
                            }
                            // Thread exhaustion: shed the connection,
                            // keep the acceptor.
                            Err(_) => {
                                if let Some(s) = streams.lock_unpoisoned().remove(&conn_id) {
                                    s.shutdown_both();
                                }
                                thread::sleep(Duration::from_millis(10));
                            }
                        }
                    }
                })
                .context("spawn router acceptor")?
        };
        Ok(Router {
            addr: resolved,
            unix_path,
            stop,
            ctl,
            shared,
            acceptor: Some(acceptor),
            conns,
            streams,
            monitors,
        })
    }

    /// The resolved upstream listen address.
    pub fn addr(&self) -> &ListenAddr {
        &self.addr
    }

    /// The upstream drain/in-flight control handle.
    pub fn ctl(&self) -> Arc<ServerCtl> {
        Arc::clone(&self.ctl)
    }

    /// The router's request ledger.
    pub fn metrics(&self) -> &RouterMetrics {
        &self.shared.metrics
    }

    /// Ledger + per-backend link state (same JSON `GetMetrics` serves).
    pub fn metrics_json(&self) -> Json {
        self.shared.metrics.to_json(&self.shared.table)
    }

    /// Block until a drain (a `Drain` frame, [`ServerCtl::drain`], or
    /// SIGTERM) stops the acceptor, then finish in-flight calls and
    /// tear down. The foreground `tmfu router` mode.
    pub fn wait(mut self) {
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        if self.ctl.is_draining() {
            // No new requests; blocked upstream readers wake with EOF
            // while write halves keep flushing in-flight replies.
            for s in self.streams.lock_unpoisoned().values() {
                s.shutdown_read();
            }
        }
        self.finish(false);
    }

    /// Stop accepting, close every upstream socket, join everything.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        self.finish(true);
    }

    fn finish(&mut self, force_close: bool) {
        if force_close {
            for s in self.streams.lock_unpoisoned().values() {
                s.shutdown_both();
            }
        }
        let conns = std::mem::take(&mut *self.conns.lock_unpoisoned());
        for c in conns {
            let _ = c.join();
        }
        self.streams.lock_unpoisoned().clear();
        // Downstream links go down only after the forwarders settle:
        // a drain wants in-flight calls to *finish*, not fail.
        for r in self.shared.table.replicas() {
            r.stop();
        }
        for m in std::mem::take(&mut self.monitors) {
            let _ = m.join();
        }
        if let Some(p) = self.unix_path.take() {
            let _ = std::fs::remove_file(&p);
        }
    }
}

// ---------------------------------------------------------------------
// Per-connection forwarder
// ---------------------------------------------------------------------

/// The request payload, kept verbatim so a retry can re-dispatch it.
enum Payload {
    Row(Vec<i32>),
    Batch(FlatBatch),
}

/// The currently outstanding downstream dispatch of an entry.
enum DownPending {
    Call(RemotePending),
    Batch(RemotePendingBatch),
}

/// One admitted upstream request, alive until it settles (one `Reply`
/// or one typed `Error` to the upstream peer, always before
/// `deadline`).
struct ForwardEntry {
    name: String,
    /// Attribution label from the upstream Hello token ("default" for
    /// anonymous connections); keys the per-tenant inflight gauge.
    tenant: Arc<str>,
    payload: Payload,
    deadline: Instant,
    /// The upstream Call carried a `deadline_us` budget: forward the
    /// remaining budget on every downstream dispatch. (The router's
    /// own `call_deadline` is never forwarded — it bounds retries
    /// locally without imposing wire deadlines on v1 backends.)
    budgeted: bool,
    /// Dispatch attempts performed so far (first attempt included).
    dispatches: u32,
    backoff: Backoff,
    pending: Option<DownPending>,
    /// Where `pending` was dispatched: replica index + link epoch, for
    /// the passive `mark_down` report on a transport-shaped failure
    /// (and the latency-EWMA credit on success).
    dispatched: Option<(usize, u64)>,
    /// When `pending` went out; the reply latency sample on success.
    dispatched_at: Option<Instant>,
    /// Set when admission dispatch failed retryably: the reactor arms
    /// this retry timer when it absorbs the registration.
    retry_at: Option<Instant>,
    /// The most recent failure; reported if the budget runs out.
    last_error: Option<ServiceError>,
}

/// State shared by an upstream connection's reader thread, its reactor
/// thread, and (through the [`Wake`] doorbell handed to every
/// downstream submit) the client demux threads completing its calls.
struct FwdShared {
    m: Mutex<FwdState>,
    cv: Condvar,
    /// Router-wide drain/in-flight accounting (mirrors the wire
    /// server's ledger; `HealthOk` reports it upstream).
    ctl: Arc<ServerCtl>,
}

struct FwdState {
    /// Immediate outbound frames from the reader (handshake, resolve
    /// and metrics replies, admission errors).
    outbox: VecDeque<Frame>,
    /// New admitted entries (upstream request id → entry).
    submitted: Vec<(u64, ForwardEntry)>,
    /// Upstream ids withdrawn by a `Cancel` frame; the reactor settles
    /// them (cancelling the downstream dispatch) without a reply.
    cancels: Vec<u64>,
    /// Upstream ids whose downstream reply became ready.
    ready: Vec<u64>,
    reader_done: bool,
    dead: bool,
}

impl FwdShared {
    fn new(ctl: Arc<ServerCtl>) -> FwdShared {
        FwdShared {
            m: Mutex::new(FwdState {
                outbox: VecDeque::new(),
                submitted: Vec::new(),
                cancels: Vec::new(),
                ready: Vec::new(),
                reader_done: false,
                dead: false,
            }),
            cv: Condvar::new(),
            ctl,
        }
    }

    fn push_frame(&self, frame: Frame) {
        let mut st = self.m.lock_unpoisoned();
        st.outbox.push_back(frame);
        drop(st);
        self.cv.notify_all();
    }

    /// Hand an admitted entry to the reactor. `false` if the
    /// connection is already dead — the caller settles the ledger.
    fn register(&self, id: u64, entry: ForwardEntry) -> bool {
        let mut st = self.m.lock_unpoisoned();
        if st.dead {
            return false;
        }
        self.ctl.inflight_add(1);
        st.submitted.push((id, entry));
        drop(st);
        self.cv.notify_all();
        true
    }

    /// The upstream peer cancelled this request id (fire-and-forget —
    /// no reply frame results, whether or not the id was in flight).
    fn push_cancel(&self, id: u64) {
        let mut st = self.m.lock_unpoisoned();
        st.cancels.push(id);
        drop(st);
        self.cv.notify_all();
    }

    fn finish_reader(&self) {
        let mut st = self.m.lock_unpoisoned();
        st.reader_done = true;
        drop(st);
        self.cv.notify_all();
    }
}

impl Wake for FwdShared {
    /// Downstream doorbell: the reply for upstream request `tag`
    /// became ready on whichever replica it was dispatched to.
    fn ring(&self, tag: u64) {
        let mut st = self.m.lock_unpoisoned();
        st.ready.push(tag);
        drop(st);
        self.cv.notify_all();
    }
}

fn forward_connection(shared: Arc<RouterShared>, stream: WireStream, ctl: Arc<ServerCtl>) {
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let control = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let _ = stream.set_read_timeout(Some(ctl.read_deadline()));
    let fwd = Arc::new(FwdShared::new(ctl));
    let reactor_shared = Arc::clone(&shared);
    let reactor_fwd = Arc::clone(&fwd);
    let spawned = thread::Builder::new()
        .name("router-react".to_string())
        .spawn(move || forward_reactor(&reactor_shared, &reactor_fwd, write_half));
    let Ok(reactor) = spawned else {
        control.shutdown_both();
        return;
    };
    let mut reader = BufReader::new(stream);
    serve_forward(&shared, &mut reader, &fwd, &control);
    fwd.finish_reader();
    let _ = reactor.join();
    control.shutdown_both();
}

/// Admit one `Call`/`CallBatch`: count it, dispatch it, and register
/// the entry with the reactor, which owns it until it settles.
fn admit(
    shared: &Arc<RouterShared>,
    fwd: &Arc<FwdShared>,
    id: u64,
    name: String,
    tenant: Arc<str>,
    payload: Payload,
    deadline_us: Option<u64>,
) {
    shared.metrics.admit();
    shared.metrics.tenant_admit(&tenant);
    let now = Instant::now();
    // A client budget tightens (never loosens) the router's own
    // per-call bound; the remaining budget is re-derived from this
    // deadline at every dispatch, so each hop sees it decremented by
    // the time already burned here.
    let budget = deadline_us.map(Duration::from_micros);
    let deadline = now + budget.map_or(shared.cfg.call_deadline, |b| b.min(shared.cfg.call_deadline));
    let mut entry = ForwardEntry {
        name,
        tenant,
        payload,
        deadline,
        budgeted: budget.is_some(),
        dispatches: 0,
        // Jitter decorrelates concurrent retries; the id keeps it
        // deterministic per request.
        backoff: Backoff::new(
            shared.cfg.backoff_base,
            shared.cfg.backoff_cap,
            id ^ 0x9e37_79b9_7f4a_7c15,
        ),
        pending: None,
        dispatched: None,
        dispatched_at: None,
        retry_at: None,
        last_error: None,
    };
    match dispatch(shared, fwd, id, &mut entry) {
        Ok(()) => {}
        Err(e) if retryable(&e) => {
            // Nothing reachable right now; park the entry on a retry
            // timer instead of failing a burst that raced a restart.
            entry.last_error = Some(e);
            entry.retry_at = Some(now + entry.backoff.next_delay());
            shared.metrics.retry();
        }
        Err(e) => {
            shared.metrics.fail(1);
            shared.metrics.tenant_settle(&entry.tenant);
            fwd.push_frame(Frame::Error {
                id,
                err: WireError::Service(e),
            });
            return;
        }
    }
    let tenant = Arc::clone(&entry.tenant);
    if !fwd.register(id, entry) {
        // Upstream connection already torn down; dropping the entry
        // abandons any downstream slot. Settled as failed so the
        // ledger still balances.
        shared.metrics.fail(1);
        shared.metrics.tenant_settle(&tenant);
    }
}

/// One dispatch attempt: pick a replica that owns the kernel and
/// submit. On a transport-shaped submit failure the replica is marked
/// down before the error propagates.
fn dispatch(
    shared: &Arc<RouterShared>,
    fwd: &Arc<FwdShared>,
    id: u64,
    entry: &mut ForwardEntry,
) -> Result<(), ServiceError> {
    entry.dispatches += 1;
    let (kernel, idx, epoch) = shared.table.pick(&entry.name)?;
    let waker: Arc<dyn Wake> = Arc::clone(fwd) as Arc<dyn Wake>;
    let now = Instant::now();
    // Budget decrement per hop: what rides the downstream frame is
    // what is left of the client's budget *now*, not what it started
    // with. (The client connection strips it for v1 backends.)
    let forward_us = entry.budgeted.then(|| {
        let remaining = entry.deadline.saturating_duration_since(now);
        // cast-ok: saturating — a remaining budget past u64::MAX
        // microseconds clamps to "effectively unbounded".
        u64::try_from(remaining.as_micros()).unwrap_or(u64::MAX)
    });
    let submitted = match &entry.payload {
        Payload::Row(inputs) => kernel
            .submit_tagged(inputs, forward_us, (waker, id))
            .map(DownPending::Call),
        Payload::Batch(batch) => kernel
            .submit_batch_tagged(batch, forward_us, (waker, id))
            .map(DownPending::Batch),
    };
    match submitted {
        Ok(pending) => {
            entry.pending = Some(pending);
            entry.dispatched = Some((idx, epoch));
            entry.dispatched_at = Some(now);
            Ok(())
        }
        Err(e) => {
            if transport_shaped(&e) {
                shared.table.replica(idx).mark_down(epoch);
            }
            Err(e)
        }
    }
}

/// Account for admitted entries a dying connection can never answer.
fn settle_failed<'a>(
    shared: &RouterShared,
    fwd: &FwdShared,
    entries: impl Iterator<Item = &'a ForwardEntry>,
) {
    let mut n = 0u64;
    for e in entries {
        n += 1;
        shared.metrics.tenant_settle(&e.tenant);
    }
    if n > 0 {
        shared.metrics.fail(n);
        fwd.ctl.inflight_sub(n);
    }
}

/// What a timer/completion decision does to its entry.
enum Outcome {
    /// Entry stays in flight (retry armed or dispatch outstanding).
    Keep,
    /// Entry settles now with this typed error.
    Settle(ServiceError),
}

/// The per-connection forwarding reactor: parks on the doorbell (or
/// the earliest retry/deadline timer), writes the reader's immediate
/// frames, polls rung completions, and drives the retry state machine.
fn forward_reactor(shared: &Arc<RouterShared>, fwd: &Arc<FwdShared>, stream: WireStream) {
    let mut w = BufWriter::new(stream);
    let mut inflight: HashMap<u64, ForwardEntry> = HashMap::new();
    // Doorbell tags that arrived before their registration; retried
    // next wake-up.
    let mut carry: Vec<u64> = Vec::new();
    // Ids cancelled after their downstream reply was already ready:
    // the doorbell rang, but the result was consumed by the cancel —
    // drop the stale ring when it surfaces. Bounded: each entry is
    // drained by exactly one ring.
    let mut stale_rings: HashSet<u64> = HashSet::new();
    // (fire time, upstream id): per-entry deadline + armed retries.
    // Linear scans — bounded by the peer's in-flight window.
    let mut timers: Vec<(Instant, u64)> = Vec::new();
    loop {
        let (mut frames, new_inflight, cancels, rung) = {
            let mut st = fwd.m.lock_unpoisoned();
            loop {
                if st.dead {
                    let orphaned = std::mem::take(&mut st.submitted);
                    drop(st);
                    settle_failed(
                        shared,
                        fwd,
                        inflight.values().chain(orphaned.iter().map(|(_, e)| e)),
                    );
                    return;
                }
                let now = Instant::now();
                let next_timer = timers.iter().map(|(t, _)| *t).min();
                let idle = st.outbox.is_empty()
                    && st.submitted.is_empty()
                    && st.cancels.is_empty()
                    && st.ready.is_empty();
                if !idle || next_timer.is_some_and(|t| t <= now) {
                    break;
                }
                if st.reader_done && inflight.is_empty() {
                    return;
                }
                st = match next_timer {
                    None => fwd.cv.wait(st).unwrap(),
                    Some(t) => {
                        let dur = t.saturating_duration_since(now);
                        fwd.cv.wait_timeout(st, dur).unwrap().0
                    }
                };
            }
            (
                std::mem::take(&mut st.outbox),
                std::mem::take(&mut st.submitted),
                std::mem::take(&mut st.cancels),
                std::mem::take(&mut st.ready),
            )
        };
        for (id, e) in new_inflight {
            timers.push((e.deadline, id));
            if let Some(t) = e.retry_at {
                timers.push((t, id));
            }
            inflight.insert(id, e);
        }
        // Upstream cancellations: settle without a reply. Dropping
        // the entry's still-outstanding downstream pending sends a
        // `Cancel` to the replica in turn (v2), so the withdrawal
        // propagates all the way to the backend's queue; a reply that
        // was already ready is consumed here and its ring dropped
        // when it surfaces.
        for id in cancels {
            let Some(mut entry) = inflight.remove(&id) else {
                // Already settled (or never admitted): a no-op.
                continue;
            };
            let ready = match entry.pending.as_mut() {
                Some(DownPending::Call(p)) => p.poll().is_some(),
                Some(DownPending::Batch(p)) => p.poll().is_some(),
                None => false,
            };
            if ready {
                stale_rings.insert(id);
            }
            shared.metrics.cancel();
            shared.metrics.tenant_settle(&entry.tenant);
            fwd.ctl.inflight_sub(1);
        }
        let mut write_err = false;
        // Reader-ordered frames first.
        for frame in frames.drain(..) {
            if write_frame(&mut w, &frame).is_err() {
                write_err = true;
                break;
            }
        }
        let mut out: Vec<Frame> = Vec::new();
        // Completions: carried tags first (their registrations may
        // have just landed), then the freshly rung.
        let tags: Vec<u64> = carry.drain(..).chain(rung).collect();
        let now = Instant::now();
        for tag in tags {
            if stale_rings.remove(&tag) {
                // The reply behind this ring was consumed by a
                // cancel; the request is already settled.
                continue;
            }
            if !inflight.contains_key(&tag) {
                // Rung before registered; the registration's notify
                // re-wakes us right after it lands.
                carry.push(tag);
                continue;
            }
            if let Some(frame) = poll_entry(shared, fwd, tag, &mut inflight, &mut timers, now) {
                out.push(frame);
            }
        }
        // Timers: deadlines and due retries.
        let now = Instant::now();
        let mut due: Vec<u64> = timers
            .iter()
            .filter(|(t, id)| *t <= now && inflight.contains_key(id))
            .map(|(_, id)| *id)
            .collect();
        timers.retain(|(t, id)| *t > now && inflight.contains_key(id));
        due.sort_unstable();
        due.dedup();
        for id in due {
            if let Some(frame) = fire_timer(shared, fwd, id, &mut inflight, &mut timers, now) {
                out.push(frame);
            }
        }
        for frame in out {
            if write_err {
                break;
            }
            if write_frame(&mut w, &frame).is_err() {
                write_err = true;
            }
        }
        if !write_err && w.flush().is_err() {
            write_err = true;
        }
        if write_err {
            // Upstream is unreachable: unblock our reader, mark the
            // connection dead, settle what remains as failed (dropping
            // the entries abandons their downstream slots).
            if let Ok(inner) = w.get_ref().try_clone() {
                inner.shutdown_both();
            }
            let mut st = fwd.m.lock_unpoisoned();
            st.dead = true;
            let orphaned = std::mem::take(&mut st.submitted);
            drop(st);
            settle_failed(
                shared,
                fwd,
                inflight.values().chain(orphaned.iter().map(|(_, e)| e)),
            );
            return;
        }
    }
}

/// Poll a rung entry's outstanding dispatch. `None` keeps it in
/// flight; `Some(frame)` is its settlement.
fn poll_entry(
    shared: &Arc<RouterShared>,
    fwd: &Arc<FwdShared>,
    tag: u64,
    inflight: &mut HashMap<u64, ForwardEntry>,
    timers: &mut Vec<(Instant, u64)>,
    now: Instant,
) -> Option<Frame> {
    let polled = {
        let entry = inflight.get_mut(&tag)?;
        match entry.pending.as_mut() {
            Some(DownPending::Call(p)) => p
                .poll()
                .map(|r| r.map(|row| FlatBatch::from_flat(row.len(), row))),
            Some(DownPending::Batch(p)) => p.poll(),
            // A ring from a dispatch this entry already abandoned
            // (e.g. it settled as Gone just as we retried): stale.
            None => None,
        }
    };
    match polled? {
        Ok(batch) => {
            let entry = inflight.remove(&tag).expect("entry vanished mid-poll");
            // Credit the replica's latency EWMA — the retry gate's
            // estimate of what one more dispatch would cost.
            if let (Some((idx, _)), Some(at)) = (entry.dispatched, entry.dispatched_at) {
                shared
                    .table
                    .replica(idx)
                    .record_latency(now.saturating_duration_since(at).as_secs_f64() * 1e6);
            }
            shared.metrics.complete();
            shared.metrics.tenant_settle(&entry.tenant);
            fwd.ctl.inflight_sub(1);
            Some(Frame::Reply { id: tag, batch })
        }
        Err(e) => {
            let outcome = {
                let entry = inflight.get_mut(&tag).expect("entry vanished mid-poll");
                // Passive health: a transport-shaped failure means the
                // link it was dispatched on is gone.
                if transport_shaped(&e) {
                    if let Some((idx, epoch)) = entry.dispatched.take() {
                        shared.table.replica(idx).mark_down(epoch);
                    }
                }
                entry.pending = None;
                entry.dispatched = None;
                entry.dispatched_at = None;
                if retryable(&e)
                    && now < entry.deadline
                    && entry.dispatches <= shared.cfg.max_retries
                    && budget_covers_retry(shared, entry, now)
                {
                    entry.last_error = Some(e);
                    timers.push((now + entry.backoff.next_delay(), tag));
                    shared.metrics.retry();
                    Outcome::Keep
                } else {
                    Outcome::Settle(e)
                }
            };
            settle(shared, fwd, tag, inflight, outcome)
        }
    }
}

/// Can the remaining deadline budget plausibly cover one more
/// dispatch? The cheapest estimate available is the fastest up
/// replica's reply-latency EWMA; with no sample yet the gate stays
/// open (optimistic, like the engine's admission feasibility check —
/// a false refusal is worse than a late expiry).
fn budget_covers_retry(shared: &RouterShared, entry: &ForwardEntry, now: Instant) -> bool {
    let best_us = shared.table.min_latency_us();
    if best_us <= 0.0 {
        return true;
    }
    let remaining = entry.deadline.saturating_duration_since(now);
    remaining.as_secs_f64() * 1e6 > best_us
}

/// A timer fired for `id`: the deadline passed, or an armed retry is
/// due.
fn fire_timer(
    shared: &Arc<RouterShared>,
    fwd: &Arc<FwdShared>,
    id: u64,
    inflight: &mut HashMap<u64, ForwardEntry>,
    timers: &mut Vec<(Instant, u64)>,
    now: Instant,
) -> Option<Frame> {
    let outcome = {
        let entry = inflight.get_mut(&id)?;
        if now >= entry.deadline {
            // Past the per-call deadline with the reply still owed:
            // settle typed. Dropping a still-outstanding pending
            // abandons its downstream slot.
            let e = match entry.last_error.take() {
                Some(e) => e,
                None => ServiceError::DeadlineExceeded {
                    kernel: entry.name.clone(),
                },
            };
            Outcome::Settle(e)
        } else if entry.pending.is_some() {
            // A retry timer armed before the current dispatch went
            // out; the deadline timer is still tracked. Spurious.
            Outcome::Keep
        } else {
            // An armed retry is due: re-dispatch — unless the budget
            // left cannot cover even the fastest replica, in which
            // case settle with the failure that armed the retry.
            if !budget_covers_retry(shared, entry, now) {
                let e = entry.last_error.take().unwrap_or(ServiceError::DeadlineExceeded {
                    kernel: entry.name.clone(),
                });
                Outcome::Settle(e)
            } else {
                match dispatch(shared, fwd, id, entry) {
                    Ok(()) => Outcome::Keep,
                    Err(e) if retryable(&e) && entry.dispatches <= shared.cfg.max_retries => {
                        entry.last_error = Some(e);
                        timers.push((now + entry.backoff.next_delay(), id));
                        shared.metrics.retry();
                        Outcome::Keep
                    }
                    Err(e) => Outcome::Settle(e),
                }
            }
        }
    };
    settle(shared, fwd, id, inflight, outcome)
}

fn settle(
    shared: &Arc<RouterShared>,
    fwd: &Arc<FwdShared>,
    id: u64,
    inflight: &mut HashMap<u64, ForwardEntry>,
    outcome: Outcome,
) -> Option<Frame> {
    match outcome {
        Outcome::Keep => None,
        Outcome::Settle(e) => {
            if let Some(entry) = inflight.remove(&id) {
                shared.metrics.tenant_settle(&entry.tenant);
            }
            shared.metrics.fail(1);
            fwd.ctl.inflight_sub(1);
            Some(Frame::Error {
                id,
                err: WireError::Service(e),
            })
        }
    }
}

/// Decode-and-dispatch loop for one upstream connection. Mirrors the
/// wire server's loop — same handshake, same patient reads, same v2
/// gating — but forwards instead of executing.
fn serve_forward(
    shared: &Arc<RouterShared>,
    reader: &mut BufReader<WireStream>,
    fwd: &Arc<FwdShared>,
    control: &WireStream,
) {
    let hello = loop {
        match read_frame_patient(reader) {
            Ok(PatientRead::Frame(f)) => break f,
            Ok(PatientRead::Eof) => return,
            Ok(PatientRead::Idle) => {
                if fwd.ctl.is_draining() {
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                fwd.push_frame(malformed(0, &e));
                return;
            }
            Err(_) => return,
        }
    };
    let (version, tenant) = match hello {
        Frame::Hello {
            id,
            min,
            max,
            token,
        } => {
            let lo = min.max(WIRE_VERSION_MIN);
            let hi = max.min(WIRE_VERSION_MAX);
            if lo > hi {
                fwd.push_frame(Frame::Error {
                    id,
                    err: WireError::VersionMismatch {
                        min: WIRE_VERSION_MIN,
                        max: WIRE_VERSION_MAX,
                    },
                });
                return;
            }
            fwd.push_frame(Frame::HelloOk {
                id,
                version: hi,
                backend: "router".to_string(),
            });
            // The router holds no keyring: an upstream token is an
            // *attribution* label for the per-tenant inflight gauge.
            // Authentication happens downstream, where the router
            // signs with its own configured credentials (a token's
            // nonce is single-use, so a client token cannot be
            // replayed toward the backends anyway).
            let tenant: Arc<str> = match token {
                Some(t) => Arc::from(t.tenant.as_str()),
                None => Arc::from("default"),
            };
            (hi, tenant)
        }
        other => {
            fwd.push_frame(malformed(
                other.request_id(),
                &format!("expected Hello, got {}", frame_name(&other)),
            ));
            return;
        }
    };

    loop {
        let frame = match read_frame_patient(reader) {
            Ok(PatientRead::Frame(f)) => f,
            Ok(PatientRead::Eof) => return,
            Ok(PatientRead::Idle) => {
                if fwd.ctl.is_draining() {
                    return;
                }
                continue;
            }
            Err(e) if e.kind() == io::ErrorKind::InvalidData => {
                fwd.push_frame(malformed(0, &e));
                return;
            }
            Err(e) if e.kind() == io::ErrorKind::TimedOut => {
                control.shutdown_both();
                return;
            }
            Err(_) => return,
        };
        match frame {
            Frame::Resolve { id, name } => {
                // Resolving through the table verifies at least one
                // healthy replica owns the kernel *now*; the arities
                // come from that replica's own resolve.
                let reply = match shared.table.pick(&name) {
                    Ok((k, _, _)) => Frame::KernelInfo {
                        id,
                        kernel: shared.intern(&name),
                        n_inputs: k.arity() as u16,
                        n_outputs: k.n_outputs() as u16,
                    },
                    Err(e) => Frame::Error {
                        id,
                        err: WireError::Service(e),
                    },
                };
                fwd.push_frame(reply);
            }
            Frame::Call {
                id,
                kernel,
                inputs,
                deadline_us,
            } => {
                if deadline_us.is_some() && version < 2 {
                    fwd.push_frame(deadline_requires_v2(id, version));
                    return;
                }
                let Some(name) = shared.name_of(kernel) else {
                    fwd.push_frame(unknown_kernel(id, kernel));
                    continue;
                };
                admit(
                    shared,
                    fwd,
                    id,
                    name,
                    Arc::clone(&tenant),
                    Payload::Row(inputs),
                    deadline_us,
                );
            }
            Frame::CallBatch {
                id,
                kernel,
                batch,
                deadline_us,
            } => {
                if deadline_us.is_some() && version < 2 {
                    fwd.push_frame(deadline_requires_v2(id, version));
                    return;
                }
                let Some(name) = shared.name_of(kernel) else {
                    fwd.push_frame(unknown_kernel(id, kernel));
                    continue;
                };
                admit(
                    shared,
                    fwd,
                    id,
                    name,
                    Arc::clone(&tenant),
                    Payload::Batch(batch),
                    deadline_us,
                );
            }
            Frame::Cancel { id } if version >= 2 => {
                fwd.push_cancel(id);
            }
            Frame::GetMetrics { id } => {
                let json = shared.metrics.to_json(&shared.table).to_string_compact();
                fwd.push_frame(Frame::Metrics { id, json });
            }
            Frame::Health { id } if version >= 2 => {
                let status = if fwd.ctl.is_draining() {
                    HEALTH_DRAINING
                } else {
                    HEALTH_SERVING
                };
                fwd.push_frame(Frame::HealthOk {
                    id,
                    status,
                    inflight: fwd.ctl.inflight().min(u32::MAX as u64) as u32,
                });
            }
            Frame::Drain { id } if version >= 2 => {
                fwd.ctl.drain();
                fwd.push_frame(Frame::HealthOk {
                    id,
                    status: HEALTH_DRAINING,
                    inflight: fwd.ctl.inflight().min(u32::MAX as u64) as u32,
                });
                return;
            }
            other @ (Frame::Health { .. } | Frame::Drain { .. } | Frame::Cancel { .. }) => {
                fwd.push_frame(malformed(
                    other.request_id(),
                    &format!(
                        "{} requires protocol v2 (negotiated v{version})",
                        frame_name(&other)
                    ),
                ));
                return;
            }
            other => {
                fwd.push_frame(malformed(
                    other.request_id(),
                    &format!("unexpected {} frame from a client", frame_name(&other)),
                ));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_classification() {
        let yes = [
            ServiceError::Disconnected {
                kernel: "fir".into(),
            },
            ServiceError::Unavailable {
                kernel: "fir".into(),
            },
            ServiceError::ShutDown,
            ServiceError::Backend {
                backend: "wire".into(),
                message: "receive failed".into(),
            },
        ];
        for e in &yes {
            assert!(retryable(e), "{e} should be retryable");
        }
        let no = [
            ServiceError::UnknownKernel("fir".into()),
            ServiceError::Backend {
                backend: "sim".into(),
                message: "engine fault".into(),
            },
        ];
        for e in &no {
            assert!(!retryable(e), "{e} should not be retryable");
        }
        // Transport-shaped is the narrower class.
        assert!(transport_shaped(&yes[0]));
        assert!(!transport_shaped(&yes[1]));
    }

    #[test]
    fn intern_is_stable_and_dense() {
        let shared = RouterShared {
            table: RoutingTable::new(vec![]),
            metrics: RouterMetrics::default(),
            cfg: RouterConfig::new(vec!["127.0.0.1:9".into()]),
            names: Mutex::new(Vec::new()),
        };
        assert_eq!(shared.intern("fir"), 0);
        assert_eq!(shared.intern("poly6"), 1);
        assert_eq!(shared.intern("fir"), 0);
        assert_eq!(shared.name_of(1).as_deref(), Some("poly6"));
        assert_eq!(shared.name_of(2), None);
    }
}
