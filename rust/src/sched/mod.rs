//! Operation scheduling (paper §IV): ASAP stage allocation onto the
//! linear FU pipeline, bypass routing, per-FU instruction generation,
//! the II/timing model and the Table-I schedule generator.

pub mod ii;
pub mod program;
pub mod route;
pub mod table1;

pub use ii::{Timing, PIPE_LATENCY};
pub use program::{Program, StageProgram};
pub use route::{Routing, ValueRoute};
pub use table1::ScheduleTable;

use crate::dfg::Dfg;
use crate::util::json::{self, Json};

/// Serialize a scheduled program (with its DFG) to the JSON interchange
/// consumed by the Python compile path (`python/compile/dfg.py`).
pub fn program_to_json(g: &Dfg, p: &Program) -> Json {
    let t = Timing::of(p);
    let stages: Vec<Json> = p
        .stages
        .iter()
        .map(|st| {
            json::obj(vec![
                ("stage", json::i(st.stage as i64)),
                ("ops", json::ints(st.ops.iter().map(|&v| v as i64))),
                (
                    "arrivals",
                    json::ints(st.arrivals.iter().map(|&v| v as i64)),
                ),
                (
                    "bypasses",
                    json::ints(st.bypasses.iter().map(|&v| v as i64)),
                ),
                (
                    "consts",
                    Json::Arr(
                        st.consts
                            .iter()
                            .map(|&(id, v)| {
                                json::obj(vec![
                                    ("node", json::i(id as i64)),
                                    ("value", json::i(v as i64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("n_loads", json::i(st.n_loads() as i64)),
                ("n_execs", json::i(st.n_execs() as i64)),
            ])
        })
        .collect();
    json::obj(vec![
        ("dfg", crate::dfg::dfg_to_json(g)),
        (
            "schedule",
            json::obj(vec![
                ("n_stages", json::i(p.n_stages() as i64)),
                ("ii", json::i(t.ii as i64)),
                ("latency", json::i(t.latency() as i64)),
                ("stages", Json::Arr(stages)),
                (
                    "output_order",
                    Json::Arr(
                        p.output_order
                            .iter()
                            .map(|(name, pos)| {
                                json::obj(vec![
                                    ("name", json::s(name)),
                                    ("pos", json::i(*pos as i64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;

    #[test]
    fn program_json_has_expected_fields() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let j = program_to_json(&g, &p);
        assert_eq!(j.get("schedule").get("ii").as_i64(), Some(11));
        assert_eq!(j.get("schedule").get("n_stages").as_i64(), Some(4));
        assert_eq!(j.get("dfg").get("name").as_str(), Some("gradient"));
        let stages = j.get("schedule").get("stages").as_arr().unwrap();
        assert_eq!(stages.len(), 4);
        assert_eq!(stages[0].get("n_loads").as_i64(), Some(5));
        // Round-trip through text stays parseable.
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("schedule").get("ii").as_i64(), Some(11));
    }
}
