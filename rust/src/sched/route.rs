//! Value routing across the linear pipeline.
//!
//! With ASAP stage allocation (one stage per FU), every value has a
//! producer stage `p` (0 for primary inputs) and a set of consumer
//! stages. A value reaches stage `p+1` for free — an op's result is
//! emitted downstream by the DSP, and inputs stream in from the FIFO —
//! but reaching a later stage requires explicit *data bypass*
//! instructions in each intervening FU (paper §III.A: "two types of
//! instruction: arithmetic or data bypass").
//!
//! Output values behave as if consumed one stage past the last FU (the
//! output FIFO), so results produced early must be bypassed to the end
//! of the pipeline.

use crate::dfg::{Dfg, Levels, NodeId};
use std::collections::BTreeMap;

/// Routing facts for one streamed (non-const) value.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValueRoute {
    pub value: NodeId,
    /// Producer stage: 0 = primary input, s >= 1 = op at stage s.
    pub producer: u32,
    /// Stages with an op consuming this value (sorted, deduped).
    pub consumer_stages: Vec<u32>,
    /// Last stage the value must reach (includes the virtual output
    /// stage `depth+1` when the value feeds a primary output).
    pub last_stage: u32,
}

impl ValueRoute {
    /// Stages whose FU must issue a bypass for this value.
    pub fn bypass_stages(&self) -> impl Iterator<Item = u32> + '_ {
        (self.producer + 1)..self.last_stage
    }

    /// Stages that receive this value into their RF
    /// (`producer+1 ..= last_stage`, capped at the real pipeline depth
    /// by the caller for the virtual output stage).
    pub fn arrival_stages(&self) -> impl Iterator<Item = u32> + '_ {
        (self.producer + 1)..=self.last_stage
    }
}

/// Routing table for a scheduled DFG.
#[derive(Debug, Clone)]
pub struct Routing {
    pub routes: BTreeMap<NodeId, ValueRoute>,
    pub depth: u32,
}

impl Routing {
    pub fn of(g: &Dfg, levels: &Levels) -> Routing {
        let depth = levels.depth;
        let mut routes: BTreeMap<NodeId, ValueRoute> = BTreeMap::new();
        // Seed producers: primary inputs (stage 0) and ops (their level).
        for id in g.ids() {
            let n = g.node(id);
            if n.is_input() || n.is_op() {
                routes.insert(
                    id,
                    ValueRoute {
                        value: id,
                        producer: if n.is_input() {
                            0
                        } else {
                            levels.level[id as usize]
                        },
                        consumer_stages: Vec::new(),
                        last_stage: 0,
                    },
                );
            }
        }
        // Consumers: op operands (non-const) and primary outputs.
        for id in g.ids() {
            let n = g.node(id);
            if n.is_op() {
                let s = levels.level[id as usize];
                for &a in &n.args {
                    if let Some(r) = routes.get_mut(&a) {
                        r.consumer_stages.push(s);
                    }
                }
            } else if n.is_output() {
                let a = n.args[0];
                let r = routes
                    .get_mut(&a)
                    .expect("output of a const is folded away by normalize");
                r.consumer_stages.push(depth + 1);
            }
        }
        for r in routes.values_mut() {
            r.consumer_stages.sort_unstable();
            r.consumer_stages.dedup();
            r.last_stage = r.consumer_stages.last().copied().unwrap_or(r.producer);
        }
        // Values with no consumers (unused inputs kept for the
        // signature): they stream in but never leave stage 1.
        for r in routes.values_mut() {
            if r.consumer_stages.is_empty() && r.producer == 0 {
                r.last_stage = 1; // loaded into FU1's RF, then dead
            }
        }
        Routing { routes, depth }
    }

    /// Values arriving into stage `s`'s RF, ordered by upstream issue
    /// order: stage-(s-1) op results first (DFG id order), then values
    /// bypassed by stage s-1 (stable id order). For s == 1 this is the
    /// input FIFO order (input declaration order).
    pub fn arrivals(&self, g: &Dfg, levels: &Levels, s: u32) -> Vec<NodeId> {
        assert!(s >= 1);
        let mut out = Vec::new();
        if s == 1 {
            // All inputs stream in, in declaration order.
            out.extend(g.inputs());
            return out;
        }
        // Results computed by stage s-1 that must reach stage s.
        for id in g.ids() {
            if g.node(id).is_op() && levels.level[id as usize] == s - 1 {
                let r = &self.routes[&id];
                if r.last_stage >= s {
                    out.push(id);
                }
            }
        }
        // Values bypassed through stage s-1.
        for (id, r) in &self.routes {
            if r.bypass_stages().any(|b| b == s - 1) {
                out.push(*id);
            }
        }
        out
    }

    /// Values stage `s`'s FU must forward with bypass instructions,
    /// in stable id order.
    pub fn bypasses(&self, s: u32) -> Vec<NodeId> {
        self.routes
            .values()
            .filter(|r| r.bypass_stages().any(|b| b == s))
            .map(|r| r.value)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::{Dfg, Levels, OpKind};

    fn chain_with_skip() -> Dfg {
        // t1 = a+b (s1); t2 = t1*c (s2); t3 = t2+a (s3): `a` skips to s3.
        let mut g = Dfg::new("skip");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let c = g.add_input("c");
        let t1 = g.add_op(OpKind::Add, a, b);
        let t2 = g.add_op(OpKind::Mul, t1, c);
        let t3 = g.add_op(OpKind::Add, t2, a);
        g.add_output("out", t3);
        g
    }

    #[test]
    fn input_bypassed_to_late_consumer() {
        let g = chain_with_skip();
        let levels = Levels::of(&g);
        let r = Routing::of(&g, &levels);
        let a_route = &r.routes[&0];
        assert_eq!(a_route.producer, 0);
        assert_eq!(a_route.consumer_stages, vec![1, 3]);
        assert_eq!(a_route.last_stage, 3);
        assert_eq!(a_route.bypass_stages().collect::<Vec<_>>(), vec![1, 2]);
        // c is consumed at stage 2 only: bypass through stage 1.
        let c_route = &r.routes[&2];
        assert_eq!(c_route.bypass_stages().collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    fn final_result_routed_to_output_fifo() {
        let g = chain_with_skip();
        let levels = Levels::of(&g);
        let r = Routing::of(&g, &levels);
        let t3 = &r.routes[&5];
        assert_eq!(t3.producer, 3);
        assert_eq!(t3.last_stage, 4); // virtual output stage depth+1
        assert_eq!(t3.bypass_stages().count(), 0);
    }

    #[test]
    fn early_output_needs_bypass_to_end() {
        // out0 = a+b (stage 1), out1 = (a+b)*c then +d (stage 3):
        // the stage-1 result must bypass through stages 2..=depth.
        let mut g = Dfg::new("early");
        let a = g.add_input("a");
        let b = g.add_input("b");
        let c = g.add_input("c");
        let d = g.add_input("d");
        let s = g.add_op(OpKind::Add, a, b);
        let m = g.add_op(OpKind::Mul, s, c);
        let f = g.add_op(OpKind::Add, m, d);
        g.add_output("early", s);
        g.add_output("late", f);
        let levels = Levels::of(&g);
        let r = Routing::of(&g, &levels);
        let s_route = &r.routes[&4];
        assert_eq!(s_route.producer, 1);
        assert_eq!(s_route.last_stage, 4); // depth 3 + 1
        assert_eq!(s_route.bypass_stages().collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn gradient_arrivals_match_table1() {
        let g = bench_suite::load("gradient").unwrap();
        let levels = Levels::of(&g);
        let r = Routing::of(&g, &levels);
        // Stage 1 receives the 5 inputs.
        assert_eq!(r.arrivals(&g, &levels, 1).len(), 5);
        // Stage 2 receives the 4 SUB results, stage 3 the 4 SQRs,
        // stage 4 the 2 ADDs; no bypasses anywhere.
        assert_eq!(r.arrivals(&g, &levels, 2).len(), 4);
        assert_eq!(r.arrivals(&g, &levels, 3).len(), 4);
        assert_eq!(r.arrivals(&g, &levels, 4).len(), 2);
        for s in 1..=4 {
            assert!(r.bypasses(s).is_empty(), "stage {s}");
        }
    }

    #[test]
    fn chebyshev_bypasses_x_down_the_chain() {
        let g = bench_suite::load("chebyshev").unwrap();
        let levels = Levels::of(&g);
        let r = Routing::of(&g, &levels);
        // x (node 0) is consumed at stages 1,2,4,5,7: bypass 1..=6.
        let x = &r.routes[&0];
        assert_eq!(x.last_stage, 7);
        assert_eq!(x.bypass_stages().collect::<Vec<_>>(), vec![1, 2, 3, 4, 5, 6]);
        // Each interior stage receives exactly {prev result, x}.
        for s in 2..=7 {
            assert_eq!(r.arrivals(&g, &levels, s).len(), 2, "stage {s}");
        }
    }

    #[test]
    fn unused_input_still_streams_in() {
        let mut g = Dfg::new("u");
        let a = g.add_input("a");
        let _unused = g.add_input("zz");
        let t = g.add_op(OpKind::Mul, a, a);
        g.add_output("o", t);
        let levels = Levels::of(&g);
        let r = Routing::of(&g, &levels);
        assert_eq!(r.arrivals(&g, &levels, 1).len(), 2);
        assert!(r.bypasses(1).is_empty());
    }
}
