//! Per-FU program construction: RF slot allocation, instruction
//! generation and the kernel context image.
//!
//! For each pipeline stage the FU's program is: the stage's arithmetic
//! instructions (DFG id order), then its data-bypass instructions. RF
//! slots are assigned by arrival order from slot 0 upward (this matches
//! the paper's sequential data counter), while constants are preloaded
//! from slot 31 downward at context-load time.

use super::route::Routing;
use crate::dfg::{Dfg, Levels, NodeId, NodeKind};
use crate::isa::{ContextImage, FuInstr};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// One pipeline stage's complete schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct StageProgram {
    /// 1-based stage index (FU index = stage - 1).
    pub stage: u32,
    /// Arithmetic ops executed here, in issue order.
    pub ops: Vec<NodeId>,
    /// Values arriving into the RF, in arrival (slot) order.
    pub arrivals: Vec<NodeId>,
    /// Values forwarded by bypass instructions, in issue order.
    pub bypasses: Vec<NodeId>,
    /// Constants preloaded into the RF: (const node, value), slot 31-.
    pub consts: Vec<(NodeId, i32)>,
    /// RF slot for every readable node (arrivals + consts).
    pub rf_slot: BTreeMap<NodeId, u8>,
    /// The FU's instruction list.
    pub instrs: Vec<FuInstr>,
}

impl StageProgram {
    /// Streamed loads into this FU per iteration.
    pub fn n_loads(&self) -> usize {
        self.arrivals.len()
    }

    /// Instructions issued per iteration.
    pub fn n_execs(&self) -> usize {
        self.instrs.len()
    }

    /// This stage's contribution to the II (see `ii.rs`).
    pub fn cost(&self) -> usize {
        self.n_loads() + self.n_execs()
    }

    /// Values this FU emits downstream, in issue order (op results
    /// then bypassed values). The next stage's `arrivals` must equal
    /// the subsequence of these that it consumes.
    pub fn emissions(&self) -> Vec<NodeId> {
        self.ops.iter().chain(self.bypasses.iter()).copied().collect()
    }
}

/// A fully scheduled kernel: per-stage programs + timing (computed by
/// [`super::ii`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub kernel: String,
    pub stages: Vec<StageProgram>,
    /// Output name -> position in the final stage's emission order.
    pub output_order: Vec<(String, usize)>,
}

impl Program {
    /// Schedule a normalized DFG onto the linear pipeline (ASAP stage
    /// allocation, the paper's policy).
    pub fn schedule(g: &Dfg) -> Result<Program> {
        Self::schedule_with(g, &Levels::of(g))
    }

    /// Schedule with ALAP stage allocation (ops sink toward their
    /// consumers; can shorten bypass chains — see `bench_ablation`).
    pub fn schedule_alap(g: &Dfg) -> Result<Program> {
        Self::schedule_with(g, &Levels::alap(g))
    }

    /// Schedule with an explicit level assignment.
    pub fn schedule_with(g: &Dfg, levels: &Levels) -> Result<Program> {
        g.validate()?;
        let levels = levels.clone();
        let routing = Routing::of(g, &levels);
        let depth = levels.depth;
        if depth == 0 {
            bail!("kernel '{}' has no operations", g.name);
        }
        let stage_ops = levels.stages(g);
        let mut stages = Vec::with_capacity(depth as usize);
        for s in 1..=depth {
            let ops = stage_ops[(s - 1) as usize].clone();
            let arrivals = routing.arrivals(g, &levels, s);
            let bypasses = routing.bypasses(s);
            // Constants read by this stage's ops.
            let mut consts: Vec<(NodeId, i32)> = Vec::new();
            for &op in &ops {
                for &a in &g.node(op).args {
                    if let NodeKind::Const { value } = g.node(a).kind {
                        if !consts.iter().any(|(id, _)| *id == a) {
                            consts.push((a, value));
                        }
                    }
                }
            }
            // RF allocation: arrivals from 0 up, consts from 31 down.
            if arrivals.len() + consts.len() > 32 {
                bail!(
                    "kernel '{}' stage {s}: RF overflow ({} arrivals + {} consts > 32)",
                    g.name,
                    arrivals.len(),
                    consts.len()
                );
            }
            let mut rf_slot = BTreeMap::new();
            for (i, &v) in arrivals.iter().enumerate() {
                rf_slot.insert(v, i as u8);
            }
            for (i, &(c, _)) in consts.iter().enumerate() {
                rf_slot.insert(c, (31 - i) as u8);
            }
            // Instructions: ops then bypasses.
            let mut instrs = Vec::new();
            for &op in &ops {
                let n = g.node(op);
                let opk = match n.kind {
                    NodeKind::Op { op } => op,
                    _ => unreachable!(),
                };
                let rs1 = *rf_slot
                    .get(&n.args[0])
                    .ok_or_else(|| anyhow::anyhow!("stage {s}: operand {} not in RF", n.args[0]))?;
                let rs2 = *rf_slot
                    .get(&n.args[1])
                    .ok_or_else(|| anyhow::anyhow!("stage {s}: operand {} not in RF", n.args[1]))?;
                instrs.push(FuInstr::Arith { op: opk, rs1, rs2 });
            }
            for &v in &bypasses {
                let rs = *rf_slot
                    .get(&v)
                    .ok_or_else(|| anyhow::anyhow!("stage {s}: bypass value {v} not in RF"))?;
                instrs.push(FuInstr::Bypass { rs });
            }
            if instrs.len() > 32 {
                bail!(
                    "kernel '{}' stage {s}: IM overflow ({} instructions > 32)",
                    g.name,
                    instrs.len()
                );
            }
            stages.push(StageProgram {
                stage: s,
                ops,
                arrivals,
                bypasses,
                consts,
                rf_slot,
                instrs,
            });
        }
        // Output order: position of each output's value in the final
        // stage's emission list.
        let last = stages.last().unwrap();
        let emissions = last.emissions();
        let mut output_order = Vec::new();
        for out_id in g.outputs() {
            let n = g.node(out_id);
            let name = match &n.kind {
                NodeKind::Output { name } => name.clone(),
                _ => unreachable!(),
            };
            let v = n.args[0];
            let pos = emissions
                .iter()
                .position(|&e| e == v)
                .ok_or_else(|| anyhow::anyhow!("output '{name}' not emitted by final stage"))?;
            output_order.push((name, pos));
        }
        Ok(Program {
            kernel: g.name.clone(),
            stages,
            output_order,
        })
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Total FUs required (== pipeline stages; the paper cascades two
    /// 8-FU pipelines when depth > 8).
    pub fn n_fus(&self) -> u32 {
        self.stages.len() as u32
    }

    /// Build the 40-bit context image for this program.
    pub fn context_image(&self) -> Result<ContextImage> {
        let mut img = ContextImage::new(&self.kernel, self.stages.len());
        for (i, st) in self.stages.iter().enumerate() {
            img.fus[i].instrs = st.instrs.clone();
            img.fus[i].consts = st.consts.iter().map(|&(_, v)| v).collect();
        }
        img.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(img)
    }

    /// Structural invariant: every stage's arrivals are exactly the
    /// upstream emissions it consumes, in order.
    pub fn check_dataflow(&self) -> Result<()> {
        for w in self.stages.windows(2) {
            let sent = w[0].emissions();
            let recv = &w[1].arrivals;
            // recv must be a subsequence of sent (an emitted value not
            // needed downstream is impossible by construction).
            let mut it = sent.iter();
            for want in recv {
                if !it.any(|got| got == want) {
                    bail!(
                        "stage {}: arrival {want} not emitted by stage {} in order",
                        w[1].stage,
                        w[0].stage
                    );
                }
            }
            if sent.len() != recv.len() {
                bail!(
                    "stage {} emits {} values but stage {} loads {}",
                    w[0].stage,
                    sent.len(),
                    w[1].stage,
                    recv.len()
                );
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dfg::OpKind;

    #[test]
    fn gradient_program_matches_table1_shape() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        assert_eq!(p.n_stages(), 4);
        let s1 = &p.stages[0];
        assert_eq!(s1.n_loads(), 5);
        assert_eq!(s1.n_execs(), 4);
        assert_eq!(
            s1.instrs.iter().map(|i| i.mnemonic()).collect::<Vec<_>>(),
            vec!["SUB (R0 R2)", "SUB (R1 R2)", "SUB (R2 R3)", "SUB (R2 R4)"]
        );
        let s2 = &p.stages[1];
        assert_eq!(
            s2.instrs.iter().map(|i| i.mnemonic()).collect::<Vec<_>>(),
            vec!["SQR (R0 R0)", "SQR (R1 R1)", "SQR (R2 R2)", "SQR (R3 R3)"]
        );
        let s3 = &p.stages[2];
        assert_eq!(
            s3.instrs.iter().map(|i| i.mnemonic()).collect::<Vec<_>>(),
            vec!["ADD (R0 R1)", "ADD (R2 R3)"]
        );
        let s4 = &p.stages[3];
        assert_eq!(
            s4.instrs.iter().map(|i| i.mnemonic()).collect::<Vec<_>>(),
            vec!["ADD (R0 R1)"]
        );
        p.check_dataflow().unwrap();
    }

    #[test]
    fn chebyshev_uses_bypass_chain() {
        let g = bench_suite::load("chebyshev").unwrap();
        let p = Program::schedule(&g).unwrap();
        assert_eq!(p.n_stages(), 7);
        // Interior stages: 1 op + 1 bypass; final stage: just the op.
        for st in &p.stages[..6] {
            assert_eq!(st.ops.len(), 1, "stage {}", st.stage);
            assert_eq!(st.bypasses.len(), 1, "stage {}", st.stage);
        }
        assert_eq!(p.stages[6].bypasses.len(), 0);
        assert!(p.stages[6].instrs.len() == 1);
        p.check_dataflow().unwrap();
    }

    #[test]
    fn consts_allocated_from_top() {
        let g = bench_suite::load("chebyshev").unwrap();
        let p = Program::schedule(&g).unwrap();
        // Stage 1: h1 = x * 16 — const 16 must sit at slot 31.
        let s1 = &p.stages[0];
        assert_eq!(s1.consts.len(), 1);
        assert_eq!(s1.consts[0].1, 16);
        assert_eq!(s1.rf_slot[&s1.consts[0].0], 31);
        match s1.instrs[0] {
            FuInstr::Arith { op, rs1, rs2 } => {
                assert_eq!(op, OpKind::Mul);
                assert_eq!(rs1, 0); // x arrives at slot 0
                assert_eq!(rs2, 31); // const 16
            }
            _ => panic!(),
        }
    }

    #[test]
    fn context_image_matches_paper_size_for_chebyshev() {
        // 13 instruction words * 5 B = 65 B — the paper's lower bound
        // for the benchmark set.
        let g = bench_suite::load("chebyshev").unwrap();
        let p = Program::schedule(&g).unwrap();
        let img = p.context_image().unwrap();
        assert_eq!(img.n_instrs(), 13);
        assert_eq!(img.size_bytes_instr_only(), 65);
    }

    #[test]
    fn all_benchmarks_schedule_cleanly() {
        for g in bench_suite::load_all().unwrap() {
            let p = Program::schedule(&g).unwrap();
            p.check_dataflow().unwrap();
            let img = p.context_image().unwrap();
            img.validate().unwrap();
            // IM depth limit respected.
            for st in &p.stages {
                assert!(st.n_execs() <= 32, "{} stage {}", g.name, st.stage);
            }
        }
    }

    #[test]
    fn output_order_resolved() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        assert_eq!(p.output_order, vec![("out".to_string(), 0)]);
    }

    #[test]
    fn context_sizes_span_paper_range() {
        // Paper §V: context data ranges 65..410 bytes across the suite.
        let mut sizes = Vec::new();
        for name in bench_suite::table2_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            sizes.push(p.context_image().unwrap().size_bytes_instr_only());
        }
        // Paper reports 65..410 B. The 65 B lower bound (chebyshev)
        // reproduces exactly; our scheduler emits fewer bypass words on
        // the biggest kernels so the upper end is smaller (favourable —
        // see EXPERIMENTS.md §ctx).
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert_eq!(min, 65, "sizes {sizes:?}");
        assert!((150..=410).contains(&max), "sizes {sizes:?}");
    }
}
