//! Initiation-interval and pipeline timing model.
//!
//! Per paper §III (validated against Table I and all of Table II):
//!
//! * each FU's iteration occupies `loads + execs` cycles (data entry,
//!   then one instruction per cycle);
//! * the DSP48E1's internal pipeline adds `FLUSH = 2` drain cycles to
//!   the bottleneck FU before the next iteration may stream in (the
//!   back-pressure window in Table I, cycles 10–11);
//! * `II = max_s(loads_s + execs_s) + FLUSH`;
//! * results issued at cycle `t` are written into the next FU's RF at
//!   `t + PIPE`, with `PIPE = 2` visible cycles (issue at 6 → load at 8
//!   in Table I).

use super::program::Program;
use crate::bench_suite::constants::FLUSH_CYCLES;

/// Visible issue→arrival offset between adjacent FUs (the DSP's
/// 3-stage internal pipeline as observed in Table I).
pub const PIPE_LATENCY: u64 = 2;

/// Timing summary for a scheduled kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct Timing {
    /// Initiation interval in cycles (between successive data packets).
    pub ii: u32,
    /// The stage that limits the II (1-based).
    pub bottleneck_stage: u32,
    /// First-arrival cycle for each stage (1-based cycle numbers;
    /// index 0 = stage 1). Matches Table I's "Load R0" rows.
    pub t_arrive: Vec<u64>,
    /// Cycle at which the first output word reaches the output FIFO.
    pub first_output: u64,
    /// Cycle at which the last output word of iteration 0 arrives.
    pub last_output: u64,
}

impl Timing {
    pub fn of(p: &Program) -> Timing {
        assert!(!p.stages.is_empty());
        let (mut ii_core, mut bottleneck) = (0usize, 1u32);
        for st in &p.stages {
            if st.cost() > ii_core {
                ii_core = st.cost();
                bottleneck = st.stage;
            }
        }
        let ii = ii_core as u32 + FLUSH_CYCLES;
        let mut t_arrive = Vec::with_capacity(p.stages.len());
        let mut t = 1u64;
        for st in &p.stages {
            t_arrive.push(t);
            t = t + st.n_loads() as u64 + PIPE_LATENCY;
        }
        let last = p.stages.last().unwrap();
        let first_output = t; // t_arrive[last] + loads + PIPE
        let last_output = first_output + last.n_execs() as u64 - 1;
        Timing {
            ii,
            bottleneck_stage: bottleneck,
            t_arrive,
            first_output,
            last_output,
        }
    }

    /// End-to-end latency of one data packet in cycles (first input
    /// word clocked in at cycle 1 → last output word).
    pub fn latency(&self) -> u64 {
        self.last_output
    }

    /// Steady-state throughput in effective operations per cycle
    /// (the paper's eOPC = DFG op nodes / II).
    pub fn eopc(&self, n_ops: usize) -> f64 {
        n_ops as f64 / self.ii as f64
    }

    /// Throughput in GOPS at a clock frequency in MHz (Table III:
    /// `ops × f / II`).
    pub fn gops(&self, n_ops: usize, freq_mhz: f64) -> f64 {
        n_ops as f64 * freq_mhz * 1e6 / self.ii as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite::{self, constants::PROPOSED_FREQ_MHZ, PAPER_ROWS};
    use crate::sched::Program;

    #[test]
    fn gradient_ii_and_arrivals_match_table1() {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let t = Timing::of(&p);
        assert_eq!(t.ii, 11);
        assert_eq!(t.bottleneck_stage, 1);
        // Table I: FU0 loads from cycle 1, FU1 from 8, FU2 from 14,
        // FU3 from 20.
        assert_eq!(t.t_arrive, vec![1, 8, 14, 20]);
        // FU3 loads 2 values (20, 21), executes its ADD at 22, result
        // reaches the output FIFO at 24.
        assert_eq!(t.first_output, 24);
        assert_eq!(t.last_output, 24);
    }

    /// The headline Table II reproduction: our scheduler's II must equal
    /// the paper's for every benchmark.
    #[test]
    fn all_benchmark_iis_match_paper() {
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let t = Timing::of(&p);
            assert_eq!(t.ii, row.ii, "{}: II {} vs paper {}", row.name, t.ii, row.ii);
        }
    }

    #[test]
    fn eopc_matches_paper_rounding() {
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let t = Timing::of(&p);
            let eopc = t.eopc(g.n_ops());
            assert!(
                (eopc - row.eopc).abs() < 0.06,
                "{}: eOPC {eopc:.2} vs paper {}",
                row.name,
                row.eopc
            );
        }
    }

    #[test]
    fn gops_matches_table3_proposed_column() {
        for row in &PAPER_ROWS {
            let g = bench_suite::load(row.name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let t = Timing::of(&p);
            let gops = t.gops(g.n_ops(), PROPOSED_FREQ_MHZ);
            assert!(
                (gops - row.tput_proposed).abs() < 0.005,
                "{}: {gops:.3} GOPS vs paper {}",
                row.name,
                row.tput_proposed
            );
        }
    }

    #[test]
    fn chebyshev_ii_is_six() {
        let g = bench_suite::load("chebyshev").unwrap();
        let p = Program::schedule(&g).unwrap();
        let t = Timing::of(&p);
        assert_eq!(t.ii, 6);
        // Interior stages cost 2 loads + 2 execs = 4; +2 flush = 6.
    }

    #[test]
    fn latency_exceeds_ii_for_deep_pipelines() {
        for name in bench_suite::table2_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let t = Timing::of(&p);
            assert!(t.latency() > t.ii as u64, "{name}");
        }
    }
}
