//! Cycle-by-cycle schedule table generator (reproduces the paper's
//! Table I for any kernel).

use super::ii::{Timing, PIPE_LATENCY};
use super::program::Program;
use crate::util::table::Table;

/// One cell of the schedule grid.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Cell(pub String);

/// The schedule grid: `grid[cycle-1][fu]` (cycles are 1-based).
#[derive(Debug, Clone)]
pub struct ScheduleTable {
    pub kernel: String,
    pub n_fus: usize,
    pub ii: u32,
    pub grid: Vec<Vec<Cell>>,
}

impl ScheduleTable {
    /// Generate the first `n_cycles` cycles of the steady-state schedule
    /// (iterations repeat every II cycles; back-pressure pauses the
    /// input FIFO exactly as in the paper).
    pub fn generate(p: &Program, n_cycles: usize) -> ScheduleTable {
        let timing = Timing::of(p);
        let ii = timing.ii as u64;
        let n_fus = p.stages.len();
        let mut grid = vec![vec![Cell::default(); n_fus]; n_cycles];
        // Enough iterations to cover the window.
        let iters = n_cycles as u64 / ii + 2;
        for (si, st) in p.stages.iter().enumerate() {
            let t0 = timing.t_arrive[si];
            for k in 0..iters {
                let base = t0 + k * ii;
                // Loads: one value per cycle into slots 0..loads.
                for (j, _) in st.arrivals.iter().enumerate() {
                    let cycle = base + j as u64;
                    if (1..=n_cycles as u64).contains(&cycle) {
                        grid[(cycle - 1) as usize][si] = Cell(format!("Load R{j}"));
                    }
                }
                // Execs: one instruction per cycle after the last load.
                let trig = base + st.n_loads() as u64;
                for (j, ins) in st.instrs.iter().enumerate() {
                    let cycle = trig + j as u64;
                    if (1..=n_cycles as u64).contains(&cycle) {
                        grid[(cycle - 1) as usize][si] = Cell(ins.mnemonic());
                    }
                }
            }
        }
        ScheduleTable {
            kernel: p.kernel.clone(),
            n_fus,
            ii: timing.ii,
            grid,
        }
    }

    /// Cell text at (1-based cycle, fu index).
    pub fn at(&self, cycle: usize, fu: usize) -> &str {
        &self.grid[cycle - 1][fu].0
    }

    /// Render in the paper's Table I format.
    pub fn render(&self) -> String {
        let mut header = vec!["cycle".to_string()];
        header.extend((0..self.n_fus).map(|i| format!("FU{i}")));
        let mut t = Table::new(&format!(
            "Schedule for '{}' (II={})",
            self.kernel, self.ii
        ))
        .header(&header);
        for (c, row) in self.grid.iter().enumerate() {
            let mut cells = vec![(c + 1).to_string()];
            cells.extend(row.iter().map(|cell| cell.0.clone()));
            t.row(&cells);
        }
        t.render()
    }

    /// The paper's back-pressure window for stage 1 of iteration 0:
    /// cycles where the input FIFO must pause (exec + flush region of
    /// the bottleneck first stage).
    pub fn backpressure_window(&self, p: &Program) -> (u64, u64) {
        let timing = Timing::of(p);
        let st = &p.stages[0];
        let start = timing.t_arrive[0] + st.n_loads() as u64;
        // Pause until the next iteration's loads may begin.
        let end = timing.t_arrive[0] + timing.ii as u64 - 1;
        let _ = PIPE_LATENCY;
        (start, end)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sched::Program;

    fn gradient_table(cycles: usize) -> (Program, ScheduleTable) {
        let g = bench_suite::load("gradient").unwrap();
        let p = Program::schedule(&g).unwrap();
        let t = ScheduleTable::generate(&p, cycles);
        (p, t)
    }

    /// Reproduce the paper's Table I cell-for-cell (first 32 cycles).
    #[test]
    fn gradient_first_32_cycles_match_paper_table1() {
        let (_, t) = gradient_table(32);
        // FU0 column.
        let fu0: [(usize, &str); 14] = [
            (1, "Load R0"),
            (2, "Load R1"),
            (3, "Load R2"),
            (4, "Load R3"),
            (5, "Load R4"),
            (6, "SUB (R0 R2)"),
            (7, "SUB (R1 R2)"),
            (8, "SUB (R2 R3)"),
            (9, "SUB (R2 R4)"),
            (12, "Load R0"),
            (13, "Load R1"),
            (14, "Load R2"),
            (15, "Load R3"),
            (16, "Load R4"),
        ];
        for (cycle, want) in fu0 {
            assert_eq!(t.at(cycle, 0), want, "FU0 cycle {cycle}");
        }
        // Idle cycles 10-11 (flush/backpressure).
        assert_eq!(t.at(10, 0), "");
        assert_eq!(t.at(11, 0), "");
        // FU1 column.
        let fu1: [(usize, &str); 8] = [
            (8, "Load R0"),
            (9, "Load R1"),
            (10, "Load R2"),
            (11, "Load R3"),
            (12, "SQR (R0 R0)"),
            (13, "SQR (R1 R1)"),
            (14, "SQR (R2 R2)"),
            (15, "SQR (R3 R3)"),
        ];
        for (cycle, want) in fu1 {
            assert_eq!(t.at(cycle, 1), want, "FU1 cycle {cycle}");
        }
        // FU2 column.
        let fu2: [(usize, &str); 6] = [
            (14, "Load R0"),
            (15, "Load R1"),
            (16, "Load R2"),
            (17, "Load R3"),
            (18, "ADD (R0 R1)"),
            (19, "ADD (R2 R3)"),
        ];
        for (cycle, want) in fu2 {
            assert_eq!(t.at(cycle, 2), want, "FU2 cycle {cycle}");
        }
        // FU3 column.
        for (cycle, want) in [(20, "Load R0"), (21, "Load R1"), (22, "ADD (R0 R1)")] {
            assert_eq!(t.at(cycle, 3), want, "FU3 cycle {cycle}");
        }
        // Iteration 2 at FU1 begins at 8 + 11 = 19.
        assert_eq!(t.at(19, 1), "Load R0");
    }

    #[test]
    fn repeats_with_period_ii() {
        // Periodicity holds once every FU has entered steady state
        // (after the deepest stage's first arrival, cycle 20).
        let (_, t) = gradient_table(64);
        for cycle in 20..=48 {
            for fu in 0..4 {
                assert_eq!(
                    t.at(cycle, fu),
                    t.at(cycle + 11, fu),
                    "cycle {cycle} fu {fu} not II-periodic"
                );
            }
        }
    }

    #[test]
    fn backpressure_window_matches_paper() {
        let (p, t) = gradient_table(16);
        // Paper: back-pressure from cycle 6 to cycle 11.
        assert_eq!(t.backpressure_window(&p), (6, 11));
    }

    #[test]
    fn render_contains_paper_cells() {
        let (_, t) = gradient_table(12);
        let s = t.render();
        assert!(s.contains("SUB (R2 R4)"));
        assert!(s.contains("FU3"));
        assert!(s.contains("II=11"));
    }

    #[test]
    fn no_cell_collisions_across_iterations() {
        // A cell written by iteration k must never be overwritten by a
        // different non-empty value from iteration k+1 (loads/execs of
        // adjacent iterations interleave but never collide).
        for name in bench_suite::all_names() {
            let g = bench_suite::load(name).unwrap();
            let p = Program::schedule(&g).unwrap();
            let t1 = ScheduleTable::generate(&p, 96);
            // Regenerating must be deterministic.
            let t2 = ScheduleTable::generate(&p, 96);
            for c in 1..=96 {
                for fu in 0..p.stages.len() {
                    assert_eq!(t1.at(c, fu), t2.at(c, fu));
                }
            }
        }
    }
}
