//! The paper's benchmark suite (Table II) plus the Fig. 1 `gradient`
//! kernel, with the paper's reported reference values for every
//! table/figure so benches can print paper-vs-measured.
//!
//! Kernel sources are embedded from `benchmarks/src/*.k` and compiled by
//! the [`crate::frontend`]. The reconstruction rationale is in DESIGN.md
//! §5 — op counts, depth, io and II are matched to the paper exactly;
//! edge counts are best-effort (they drive nothing downstream).

use crate::dfg::Dfg;
use crate::frontend;

/// Paper-reported Table II row (plus Table III / Fig. 5 columns).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub name: &'static str,
    /// Table II
    pub io_in: usize,
    pub io_out: usize,
    pub edges: usize,
    pub ops: usize,
    pub depth: u32,
    pub parallelism: f64,
    pub ii: u32,
    pub eopc: f64,
    /// Table III: throughput (GOPS) and area (e-Slices)
    pub tput_proposed: f64,
    pub area_proposed: u32,
    pub tput_scfu: f64,
    pub area_scfu: u32,
    pub tput_hls: f64,
    pub area_hls: u32,
    /// Fig. 5: FUs required (proposed = pipeline stages used; SCFU-SCN
    /// counts back-derived from Table III area / 190 e-Slices per FU).
    pub fus_proposed: u32,
    pub fus_scfu: u32,
}

/// The 8 rows of Table II / Table III, as printed in the paper.
pub const PAPER_ROWS: [PaperRow; 8] = [
    PaperRow {
        name: "chebyshev",
        io_in: 1,
        io_out: 1,
        edges: 12,
        ops: 7,
        depth: 7,
        parallelism: 1.00,
        ii: 6,
        eopc: 1.2,
        tput_proposed: 0.35,
        area_proposed: 987,
        tput_scfu: 2.35,
        area_scfu: 1900,
        tput_hls: 2.21,
        area_hls: 265,
        fus_proposed: 7,
        fus_scfu: 10,
    },
    PaperRow {
        name: "sgfilter",
        io_in: 2,
        io_out: 1,
        edges: 27,
        ops: 18,
        depth: 9,
        parallelism: 2.00,
        ii: 10,
        eopc: 1.8,
        tput_proposed: 0.54,
        area_proposed: 1269,
        tput_scfu: 6.03,
        area_scfu: 4560,
        tput_hls: 4.59,
        area_hls: 645,
        fus_proposed: 9,
        fus_scfu: 24,
    },
    PaperRow {
        name: "mibench",
        io_in: 3,
        io_out: 1,
        edges: 22,
        ops: 13,
        depth: 6,
        parallelism: 2.16,
        ii: 11,
        eopc: 1.2,
        tput_proposed: 0.35,
        area_proposed: 846,
        tput_scfu: 4.36,
        area_scfu: 3040,
        tput_hls: 3.51,
        area_hls: 305,
        fus_proposed: 6,
        fus_scfu: 16,
    },
    PaperRow {
        name: "qspline",
        io_in: 7,
        io_out: 1,
        edges: 50,
        ops: 26,
        depth: 8,
        parallelism: 3.25,
        ii: 18,
        eopc: 1.4,
        tput_proposed: 0.43,
        area_proposed: 1128,
        tput_scfu: 8.71,
        area_scfu: 8360,
        tput_hls: 6.11,
        area_hls: 1270,
        fus_proposed: 8,
        fus_scfu: 44,
    },
    PaperRow {
        name: "poly5",
        io_in: 3,
        io_out: 1,
        edges: 43,
        ops: 27,
        depth: 9,
        parallelism: 3.00,
        ii: 14,
        eopc: 1.9,
        tput_proposed: 0.58,
        area_proposed: 1269,
        tput_scfu: 9.05,
        area_scfu: 6460,
        tput_hls: 7.02,
        area_hls: 765,
        fus_proposed: 9,
        fus_scfu: 34,
    },
    PaperRow {
        name: "poly6",
        io_in: 3,
        io_out: 1,
        edges: 72,
        ops: 44,
        depth: 11,
        parallelism: 4.00,
        ii: 17,
        eopc: 2.6,
        tput_proposed: 0.78,
        area_proposed: 1551,
        tput_scfu: 14.74,
        area_scfu: 11400,
        tput_hls: 11.88,
        area_hls: 1455,
        fus_proposed: 11,
        fus_scfu: 60,
    },
    PaperRow {
        name: "poly7",
        io_in: 3,
        io_out: 1,
        edges: 62,
        ops: 39,
        depth: 13,
        parallelism: 3.00,
        ii: 17,
        eopc: 2.3,
        tput_proposed: 0.69,
        area_proposed: 1833,
        tput_scfu: 13.07,
        area_scfu: 10640,
        tput_hls: 10.92,
        area_hls: 1025,
        fus_proposed: 13,
        fus_scfu: 56,
    },
    PaperRow {
        name: "poly8",
        io_in: 3,
        io_out: 1,
        edges: 51,
        ops: 32,
        depth: 11,
        parallelism: 2.90,
        ii: 15,
        eopc: 2.1,
        tput_proposed: 0.64,
        area_proposed: 1551,
        tput_scfu: 10.72,
        area_scfu: 7220,
        tput_hls: 8.32,
        area_hls: 1025,
        fus_proposed: 11,
        fus_scfu: 38,
    },
];

/// Embedded kernel sources (name, source text). `gradient` (Fig. 1 /
/// Table I) is part of the suite but not a Table II row.
pub const KERNEL_SOURCES: [(&str, &str); 9] = [
    ("gradient", include_str!("../../../benchmarks/src/gradient.k")),
    ("chebyshev", include_str!("../../../benchmarks/src/chebyshev.k")),
    ("sgfilter", include_str!("../../../benchmarks/src/sgfilter.k")),
    ("mibench", include_str!("../../../benchmarks/src/mibench.k")),
    ("qspline", include_str!("../../../benchmarks/src/qspline.k")),
    ("poly5", include_str!("../../../benchmarks/src/poly5.k")),
    ("poly6", include_str!("../../../benchmarks/src/poly6.k")),
    ("poly7", include_str!("../../../benchmarks/src/poly7.k")),
    ("poly8", include_str!("../../../benchmarks/src/poly8.k")),
];

/// Names of the Table II benchmarks, in paper order.
pub fn table2_names() -> Vec<&'static str> {
    PAPER_ROWS.iter().map(|r| r.name).collect()
}

/// All kernel names (gradient first).
pub fn all_names() -> Vec<&'static str> {
    KERNEL_SOURCES.iter().map(|(n, _)| *n).collect()
}

/// Compile one benchmark kernel by name.
pub fn load(name: &str) -> crate::Result<Dfg> {
    let (_, src) = KERNEL_SOURCES
        .iter()
        .find(|(n, _)| *n == name)
        .ok_or_else(|| anyhow::anyhow!("unknown benchmark kernel '{name}'"))?;
    Ok(frontend::compile(src).map_err(|e| anyhow::anyhow!("{name}: {e}"))?)
}

/// Compile every benchmark kernel (gradient + the Table II eight).
pub fn load_all() -> crate::Result<Vec<Dfg>> {
    all_names().into_iter().map(load).collect()
}

/// Paper row lookup.
pub fn paper_row(name: &str) -> Option<&'static PaperRow> {
    PAPER_ROWS.iter().find(|r| r.name == name)
}

/// Paper constants used across the evaluation (§V, DESIGN.md §6).
pub mod constants {
    /// Overlay operating frequency used in Table III throughput (MHz).
    pub const PROPOSED_FREQ_MHZ: f64 = 300.0;
    /// SCFU-SCN overlay frequency implied by Table III (MHz).
    pub const SCFU_FREQ_MHZ: f64 = 335.0;
    /// e-Slices per proposed FU: 1 DSP (=60 slices) + 81 slices.
    pub const PROPOSED_FU_ESLICES: u32 = 141;
    /// e-Slices per SCFU-SCN FU (from [13], back-derived from Table III).
    pub const SCFU_FU_ESLICES: u32 = 190;
    /// 1 DSP block == 60 slices on the Zynq XC7Z020 (paper §V).
    pub const SLICES_PER_DSP: u32 = 60;
    /// Max FUs in one linear pipeline (Fig. 2/4); deeper kernels cascade
    /// two pipelines.
    pub const PIPELINE_FUS: u32 = 8;
    /// DSP48E1 internal pipeline flush cycles added to each FU's II.
    pub const FLUSH_CYCLES: u32 = 2;
    /// Context word width (32-bit instruction + 8-bit tag).
    pub const CONTEXT_WORD_BITS: u32 = 40;
    /// Instruction memory depth per FU (RAM32M => 32 entries).
    pub const IM_DEPTH: usize = 32;
    /// Register file depth per FU.
    pub const RF_DEPTH: usize = 32;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::{eval, Characteristics};

    #[test]
    fn all_kernels_compile_and_validate() {
        for g in load_all().unwrap() {
            assert!(g.validate().is_ok(), "{}", g.name);
            assert!(g.n_ops() > 0);
        }
    }

    /// The core Table II reproduction: io / ops / depth / parallelism
    /// must match the paper exactly for every benchmark.
    #[test]
    fn table2_structural_characteristics_match_paper() {
        for row in &PAPER_ROWS {
            let g = load(row.name).unwrap();
            let c = Characteristics::of(&g);
            assert_eq!(c.n_inputs, row.io_in, "{} inputs", row.name);
            assert_eq!(c.n_outputs, row.io_out, "{} outputs", row.name);
            assert_eq!(c.n_ops, row.ops, "{} ops", row.name);
            assert_eq!(c.depth, row.depth, "{} depth", row.name);
            assert!(
                (c.avg_parallelism - row.parallelism).abs() < 0.01,
                "{} parallelism {} vs {}",
                row.name,
                c.avg_parallelism,
                row.parallelism
            );
        }
    }

    #[test]
    fn edges_within_tolerance_of_paper() {
        // Edge counting conventions in the paper's tool are unknown;
        // we require ±10% (see DESIGN.md §5).
        let mut failures = Vec::new();
        for row in &PAPER_ROWS {
            let g = load(row.name).unwrap();
            let c = Characteristics::of(&g);
            let delta = (c.n_edges as f64 - row.edges as f64) / row.edges as f64;
            if delta.abs() > 0.10 {
                failures.push(format!(
                    "{}: edges {} vs paper {} ({:+.0}%)",
                    row.name,
                    c.n_edges,
                    row.edges,
                    delta * 100.0
                ));
            }
        }
        assert!(failures.is_empty(), "{}", failures.join("\n"));
    }

    #[test]
    fn gradient_matches_fig1() {
        let g = load("gradient").unwrap();
        let c = Characteristics::of(&g);
        assert_eq!(c.n_inputs, 5);
        assert_eq!(c.n_ops, 11);
        assert_eq!(c.depth, 4);
        // (r0-r2)^2 + (r1-r2)^2 + (r2-r3)^2 + (r2-r4)^2
        assert_eq!(eval(&g, &[3, 5, 2, 7, 1]), vec![1 + 9 + 25 + 1]);
    }

    #[test]
    fn kernels_evaluate_reasonably() {
        // chebyshev: T5-scaled polynomial identity at x=2.
        let cheb = load("chebyshev").unwrap();
        assert_eq!(eval(&cheb, &[2]), vec![16 * 32 - 20 * 8 + 10]);
        // All kernels: deterministic results, no panics at extremes.
        for g in load_all().unwrap() {
            let n = g.inputs().len();
            let _ = eval(&g, &vec![i32::MAX; n]);
            let _ = eval(&g, &vec![i32::MIN; n]);
            let _ = eval(&g, &vec![0; n]);
        }
    }

    #[test]
    fn eopc_consistent_with_paper_rounding() {
        for row in &PAPER_ROWS {
            let eopc = row.ops as f64 / row.ii as f64;
            assert!(
                (eopc - row.eopc).abs() < 0.06,
                "{}: {} vs {}",
                row.name,
                eopc,
                row.eopc
            );
        }
    }

    #[test]
    fn paper_area_identity_holds() {
        // Table III proposed area == FUs * 141 e-Slices for every row.
        for row in &PAPER_ROWS {
            assert_eq!(
                row.area_proposed,
                row.fus_proposed * constants::PROPOSED_FU_ESLICES,
                "{}",
                row.name
            );
            assert_eq!(row.area_scfu, row.fus_scfu * constants::SCFU_FU_ESLICES, "{}", row.name);
        }
    }

    #[test]
    fn unknown_kernel_is_error() {
        assert!(load("nonesuch").is_err());
    }
}
