//! DSP48E1 configuration word (the 21-bit field of the FU instruction).
//!
//! The paper's FU stores the DSP block's control inputs directly in the
//! instruction ("as instruction decoders are not used the instruction
//! format must explicitly specify ... the modes of operation of the DSP
//! block directly"). We model the real DSP48E1 control groups:
//!
//! | bits    | field      | meaning                                   |
//! |---------|------------|-------------------------------------------|
//! | [6:0]   | OPMODE     | X/Y/Z multiplexer select                  |
//! | [10:7]  | ALUMODE    | ALU function (add/sub/logic)              |
//! | [15:11] | INMODE     | A/B input register path select            |
//! | [18:16] | CARRYINSEL | carry source                              |
//! | [19]    | USE_MULT   | multiplier path active                    |
//! | [20]    | reserved   |                                           |
//!
//! The concrete encodings below follow the DSP48E1 user guide's
//! conventions (X=M/Y=M for multiply, Z=C with ALUMODE add/sub for the
//! adder path, logic-unit ALUMODE patterns for AND/OR/XOR); they are the
//! single source of truth shared by the encoder, the decoder and the
//! cycle-accurate DSP model.

use crate::dfg::OpKind;
use crate::util::bits::{get_field, set_field};

/// Decoded DSP48E1 control word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DspConfig {
    pub opmode: u8,      // 7 bits
    pub alumode: u8,     // 4 bits
    pub inmode: u8,      // 5 bits
    pub carryinsel: u8,  // 3 bits
    pub use_mult: bool,
}

/// OPMODE with X=A:B, Y=0, Z=C — the adder/logic path.
pub const OPMODE_ADDPATH: u8 = 0b011_00_11;
/// OPMODE with X=M, Y=M, Z=0 — the multiplier path.
pub const OPMODE_MULPATH: u8 = 0b000_01_01;
/// OPMODE with X=0, Y=0, Z=C — route C straight through (bypass).
pub const OPMODE_PASS_C: u8 = 0b011_00_00;
/// OPMODE variant with Y = all-ones (used by the OR encoding).
pub const OPMODE_ADDPATH_YONES: u8 = 0b011_10_11;

/// ALUMODE: Z + X + Y + CIN.
pub const ALUMODE_ADD: u8 = 0b0000;
/// ALUMODE: Z - (X + Y + CIN).
pub const ALUMODE_SUB: u8 = 0b0011;
/// ALUMODE logic: X XOR Z.
pub const ALUMODE_XOR: u8 = 0b0100;
/// ALUMODE logic: X AND Z.
pub const ALUMODE_AND: u8 = 0b1100;
/// ALUMODE logic: X OR Z (AND pattern with Y=all-ones per UG479 table).
pub const ALUMODE_OR: u8 = 0b1100;

impl DspConfig {
    /// The configuration driving the DSP for an arithmetic op.
    ///
    /// Operand routing convention (fixed by the FU datapath, Fig. 3):
    /// `rs1` drives the C port, `rs2` drives A:B (and the multiplier's
    /// A×B path uses both register file read ports).
    pub fn for_op(op: OpKind) -> DspConfig {
        let (opmode, alumode, use_mult) = match op {
            OpKind::Add => (OPMODE_ADDPATH, ALUMODE_ADD, false),
            OpKind::Sub => (OPMODE_ADDPATH, ALUMODE_SUB, false),
            OpKind::Mul => (OPMODE_MULPATH, ALUMODE_ADD, true),
            OpKind::And => (OPMODE_ADDPATH, ALUMODE_AND, false),
            OpKind::Or => (OPMODE_ADDPATH_YONES, ALUMODE_OR, false),
            OpKind::Xor => (OPMODE_ADDPATH, ALUMODE_XOR, false),
        };
        DspConfig {
            opmode,
            alumode,
            inmode: 0,
            carryinsel: 0,
            use_mult,
        }
    }

    /// Bypass configuration: route the C register straight to P.
    pub fn bypass() -> DspConfig {
        DspConfig {
            opmode: OPMODE_PASS_C,
            alumode: ALUMODE_ADD,
            inmode: 0,
            carryinsel: 0,
            use_mult: false,
        }
    }

    /// Recover the op this configuration computes (`None` == bypass,
    /// `Err`-like `None` for malformed words is handled by the caller).
    pub fn classify(&self) -> Option<Option<OpKind>> {
        if self.use_mult {
            return if self.opmode == OPMODE_MULPATH && self.alumode == ALUMODE_ADD {
                Some(Some(OpKind::Mul))
            } else {
                None
            };
        }
        match (self.opmode, self.alumode) {
            (OPMODE_PASS_C, ALUMODE_ADD) => Some(None),
            (OPMODE_ADDPATH, ALUMODE_ADD) => Some(Some(OpKind::Add)),
            (OPMODE_ADDPATH, ALUMODE_SUB) => Some(Some(OpKind::Sub)),
            (OPMODE_ADDPATH, ALUMODE_AND) => Some(Some(OpKind::And)),
            (OPMODE_ADDPATH_YONES, ALUMODE_OR) => Some(Some(OpKind::Or)),
            (OPMODE_ADDPATH, ALUMODE_XOR) => Some(Some(OpKind::Xor)),
            _ => None,
        }
    }

    /// Pack into the instruction's 21-bit field.
    pub fn encode(&self) -> u32 {
        let mut w = 0u64;
        w = set_field(w, 0, 7, self.opmode as u64);
        w = set_field(w, 7, 4, self.alumode as u64);
        w = set_field(w, 11, 5, self.inmode as u64);
        w = set_field(w, 16, 3, self.carryinsel as u64);
        w = set_field(w, 19, 1, self.use_mult as u64);
        w as u32
    }

    /// Unpack from the 21-bit field.
    pub fn decode(bits: u32) -> DspConfig {
        let w = bits as u64;
        DspConfig {
            opmode: get_field(w, 0, 7) as u8,
            alumode: get_field(w, 7, 4) as u8,
            inmode: get_field(w, 11, 5) as u8,
            carryinsel: get_field(w, 16, 3) as u8,
            use_mult: get_field(w, 19, 1) != 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_round_trip_all_ops() {
        for op in OpKind::ALL {
            let cfg = DspConfig::for_op(op);
            let bits = cfg.encode();
            assert!(bits < (1 << 21), "{op}: config exceeds 21 bits");
            assert_eq!(DspConfig::decode(bits), cfg, "{op}");
            assert_eq!(cfg.classify(), Some(Some(op)), "{op}");
        }
    }

    #[test]
    fn bypass_round_trips() {
        let cfg = DspConfig::bypass();
        assert_eq!(DspConfig::decode(cfg.encode()), cfg);
        assert_eq!(cfg.classify(), Some(None));
    }

    #[test]
    fn distinct_ops_have_distinct_encodings() {
        let mut seen = std::collections::BTreeSet::new();
        for op in OpKind::ALL {
            assert!(seen.insert(DspConfig::for_op(op).encode()), "{op} collides");
        }
        assert!(seen.insert(DspConfig::bypass().encode()), "bypass collides");
    }

    #[test]
    fn malformed_config_classifies_none() {
        let bogus = DspConfig {
            opmode: 0b1111111,
            alumode: 0b1010,
            inmode: 0,
            carryinsel: 0,
            use_mult: false,
        };
        assert_eq!(bogus.classify(), None);
    }

    #[test]
    fn mult_path_flag_checked() {
        // use_mult with an adder opmode is malformed.
        let bogus = DspConfig {
            opmode: OPMODE_ADDPATH,
            alumode: ALUMODE_ADD,
            inmode: 0,
            carryinsel: 0,
            use_mult: true,
        };
        assert_eq!(bogus.classify(), None);
    }
}
