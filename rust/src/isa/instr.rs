//! The 32-bit FU instruction.
//!
//! Layout (paper §III.A: "A 32-bit instruction has two parts, the 21-bit
//! DSP block configuration and two 5-bit source operand addresses"):
//!
//! | bits    | field                                   |
//! |---------|-----------------------------------------|
//! | [20:0]  | DSP48E1 configuration ([`DspConfig`])   |
//! | [25:21] | `rs1` — register file read address 1    |
//! | [30:26] | `rs2` — register file read address 2    |
//! | [31]    | spare (must be 0)                       |
//!
//! Two instruction kinds exist (paper: "arithmetic or data bypass");
//! the kind is implied by the DSP configuration, not a separate field —
//! a bypass is the `Z=C` pass-through configuration.

use super::dsp_config::DspConfig;
use crate::dfg::OpKind;
use crate::util::bits::{get_field, set_field};

/// Decoded FU instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuInstr {
    /// Compute `op(RF[rs1], RF[rs2])` and emit the result downstream.
    Arith { op: OpKind, rs1: u8, rs2: u8 },
    /// Forward `RF[rs]` downstream unchanged.
    Bypass { rs: u8 },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstrError {
    RegRange(u8),
    BadConfig(u32),
    SpareBit(u32),
}

impl std::fmt::Display for InstrError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InstrError::RegRange(r) => {
                write!(f, "register address {r} out of range (RF has 32 entries)")
            }
            InstrError::BadConfig(w) => write!(f, "word {w:#010x}: unrecognized DSP configuration"),
            InstrError::SpareBit(w) => write!(f, "word {w:#010x}: spare bit set"),
        }
    }
}

impl std::error::Error for InstrError {}

impl FuInstr {
    /// The DSP configuration this instruction drives.
    pub fn dsp_config(&self) -> DspConfig {
        match self {
            FuInstr::Arith { op, .. } => DspConfig::for_op(*op),
            FuInstr::Bypass { .. } => DspConfig::bypass(),
        }
    }

    /// Register file addresses read by this instruction.
    pub fn reads(&self) -> (u8, Option<u8>) {
        match self {
            FuInstr::Arith { rs1, rs2, .. } => (*rs1, Some(*rs2)),
            FuInstr::Bypass { rs } => (*rs, None),
        }
    }

    pub fn is_bypass(&self) -> bool {
        matches!(self, FuInstr::Bypass { .. })
    }

    /// Encode to the 32-bit word.
    pub fn encode(&self) -> Result<u32, InstrError> {
        let (cfg, rs1, rs2) = match self {
            FuInstr::Arith { op, rs1, rs2 } => (DspConfig::for_op(*op), *rs1, *rs2),
            FuInstr::Bypass { rs } => (DspConfig::bypass(), *rs, 0),
        };
        for r in [rs1, rs2] {
            if r >= 32 {
                return Err(InstrError::RegRange(r));
            }
        }
        let mut w = 0u64;
        w = set_field(w, 0, 21, cfg.encode() as u64);
        w = set_field(w, 21, 5, rs1 as u64);
        w = set_field(w, 26, 5, rs2 as u64);
        Ok(w as u32)
    }

    /// Decode from the 32-bit word.
    pub fn decode(word: u32) -> Result<FuInstr, InstrError> {
        let w = word as u64;
        if get_field(w, 31, 1) != 0 {
            return Err(InstrError::SpareBit(word));
        }
        let cfg = DspConfig::decode(get_field(w, 0, 21) as u32);
        let rs1 = get_field(w, 21, 5) as u8;
        let rs2 = get_field(w, 26, 5) as u8;
        match cfg.classify() {
            Some(Some(op)) => Ok(FuInstr::Arith { op, rs1, rs2 }),
            Some(None) => Ok(FuInstr::Bypass { rs: rs1 }),
            None => Err(InstrError::BadConfig(word)),
        }
    }

    /// Human-readable form matching the paper's Table I notation.
    pub fn mnemonic(&self) -> String {
        match self {
            FuInstr::Arith { op, rs1, rs2 } => {
                if op == &OpKind::Mul && rs1 == rs2 {
                    format!("SQR (R{rs1} R{rs2})")
                } else {
                    format!("{} (R{rs1} R{rs2})", op.name().to_uppercase())
                }
            }
            FuInstr::Bypass { rs } => format!("BYP (R{rs})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arith_round_trips_all_ops_and_regs() {
        for op in OpKind::ALL {
            for (rs1, rs2) in [(0u8, 0u8), (31, 31), (5, 17), (31, 0)] {
                let i = FuInstr::Arith { op, rs1, rs2 };
                let w = i.encode().unwrap();
                assert_eq!(FuInstr::decode(w).unwrap(), i);
            }
        }
    }

    #[test]
    fn bypass_round_trips() {
        for rs in [0u8, 1, 31] {
            let i = FuInstr::Bypass { rs };
            assert_eq!(FuInstr::decode(i.encode().unwrap()).unwrap(), i);
        }
    }

    #[test]
    fn rejects_out_of_range_registers() {
        let i = FuInstr::Arith {
            op: OpKind::Add,
            rs1: 32,
            rs2: 0,
        };
        assert_eq!(i.encode(), Err(InstrError::RegRange(32)));
    }

    #[test]
    fn rejects_spare_bit() {
        assert_eq!(FuInstr::decode(0x8000_0000), Err(InstrError::SpareBit(0x8000_0000)));
    }

    #[test]
    fn rejects_garbage_config() {
        // ALUMODE 0b1010 with adder opmode is not a valid encoding.
        let garbage = 0b0101_0_0110011u32 << 0 | (0b1010 << 7);
        assert!(matches!(FuInstr::decode(garbage), Err(InstrError::BadConfig(_))));
    }

    #[test]
    fn mnemonics_match_paper_style() {
        let sub = FuInstr::Arith {
            op: OpKind::Sub,
            rs1: 0,
            rs2: 2,
        };
        assert_eq!(sub.mnemonic(), "SUB (R0 R2)");
        let sqr = FuInstr::Arith {
            op: OpKind::Mul,
            rs1: 1,
            rs2: 1,
        };
        assert_eq!(sqr.mnemonic(), "SQR (R1 R1)");
        assert_eq!(FuInstr::Bypass { rs: 3 }.mnemonic(), "BYP (R3)");
    }

    #[test]
    fn exhaustive_decode_never_panics() {
        // Sweep a structured sample of the 32-bit space.
        for hi in 0..64u32 {
            for lo in 0..64u32 {
                let w = (hi << 26) | (lo << 15) | (hi * 31 + lo);
                let _ = FuInstr::decode(w); // Ok or Err, never panic
            }
        }
    }
}
