//! The overlay ISA: DSP48E1 configuration words, 32-bit FU instructions
//! and the 40-bit context stream (paper §III.A).

pub mod context;
pub mod dsp_config;
pub mod instr;

pub use context::{ContextError, ContextImage, ContextWord, FuContext};
pub use dsp_config::DspConfig;
pub use instr::{FuInstr, InstrError};
