//! Kernel context: the 40-bit configuration stream.
//!
//! At initialization or on a hardware context switch, 40-bit words —
//! a 32-bit payload plus an 8-bit tag matching the word to its FU —
//! are clocked down the daisy-chained instruction ports (paper §III.A).
//!
//! Tag layout: `tag[4:0]` = FU index in the pipeline (0–31),
//! `tag[7:5]` = word kind (0 = instruction, 1 = RF constant preload).
//! Constant preloads fill the register file from slot 31 downward in
//! stream order; the paper does not specify how constants reach the RF
//! (its context byte counts cover instructions only), so we model them
//! as extra context words and report both accountings (DESIGN.md §5).

use super::instr::{FuInstr, InstrError};
use crate::util::bits::{BitReader, BitWriter};

/// Word kind encoded in tag[7:5].
const KIND_INSTR: u8 = 0;
const KIND_CONST: u8 = 1;

/// One 40-bit context word.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ContextWord {
    pub tag: u8,
    pub payload: u32,
}

impl ContextWord {
    pub fn instr(fu: u8, instr: &FuInstr) -> Result<ContextWord, InstrError> {
        assert!(fu < 32, "fu index {fu} exceeds tag field");
        Ok(ContextWord {
            tag: (KIND_INSTR << 5) | fu,
            payload: instr.encode()?,
        })
    }

    pub fn rf_const(fu: u8, value: i32) -> ContextWord {
        assert!(fu < 32);
        ContextWord {
            tag: (KIND_CONST << 5) | fu,
            payload: value as u32,
        }
    }

    pub fn fu_index(&self) -> u8 {
        self.tag & 0x1F
    }

    pub fn kind(&self) -> u8 {
        self.tag >> 5
    }

    pub fn as_u64(&self) -> u64 {
        ((self.tag as u64) << 32) | self.payload as u64
    }

    pub fn from_u64(w: u64) -> ContextWord {
        ContextWord {
            tag: ((w >> 32) & 0xFF) as u8,
            payload: (w & 0xFFFF_FFFF) as u32,
        }
    }
}

/// Per-FU context contents.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FuContext {
    pub instrs: Vec<FuInstr>,
    /// Constants preloaded into the RF, slot 31 downward.
    pub consts: Vec<i32>,
}

/// A complete kernel context for one pipeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ContextImage {
    pub kernel: String,
    pub fus: Vec<FuContext>,
}

#[derive(Debug, Clone)]
pub enum ContextError {
    Instr(InstrError),
    Truncated,
    BadKind(usize, u8),
    ImOverflow(usize),
    RfOverflow(usize),
}

impl std::fmt::Display for ContextError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ContextError::Instr(e) => write!(f, "{e}"),
            ContextError::Truncated => f.write_str("context stream truncated"),
            ContextError::BadKind(w, k) => write!(f, "word {w}: unknown kind {k}"),
            ContextError::ImOverflow(fu) => {
                write!(f, "FU {fu}: more than 32 instructions do not fit the IM")
            }
            ContextError::RfOverflow(fu) => {
                write!(f, "FU {fu}: RF constant preload exceeds register file")
            }
        }
    }
}

impl std::error::Error for ContextError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ContextError::Instr(e) => Some(e),
            _ => None,
        }
    }
}

impl From<InstrError> for ContextError {
    fn from(e: InstrError) -> ContextError {
        ContextError::Instr(e)
    }
}

impl ContextImage {
    pub fn new(kernel: &str, n_fus: usize) -> Self {
        assert!(n_fus <= 32, "pipeline limited to 32 FUs by the tag field");
        ContextImage {
            kernel: kernel.to_string(),
            fus: vec![FuContext::default(); n_fus],
        }
    }

    pub fn n_fus(&self) -> usize {
        self.fus.len()
    }

    /// Total instruction count across FUs.
    pub fn n_instrs(&self) -> usize {
        self.fus.iter().map(|f| f.instrs.len()).sum()
    }

    /// Validate IM/RF capacity limits (32-entry IM, 32-entry RF).
    pub fn validate(&self) -> Result<(), ContextError> {
        for (i, fu) in self.fus.iter().enumerate() {
            if fu.instrs.len() > 32 {
                return Err(ContextError::ImOverflow(i));
            }
            if fu.consts.len() > 32 {
                return Err(ContextError::RfOverflow(i));
            }
        }
        Ok(())
    }

    /// The full 40-bit word stream, FU by FU (daisy-chain order:
    /// farthest FU first so each word shifts into place).
    pub fn words(&self) -> Result<Vec<ContextWord>, ContextError> {
        let mut out = Vec::new();
        for (i, fu) in self.fus.iter().enumerate().rev() {
            for ins in &fu.instrs {
                out.push(ContextWord::instr(i as u8, ins)?);
            }
            for &c in &fu.consts {
                out.push(ContextWord::rf_const(i as u8, c));
            }
        }
        Ok(out)
    }

    /// Paper accounting: instruction words only, 5 bytes per 40-bit word
    /// (§V reports 65–410 B for the benchmark suite).
    pub fn size_bytes_instr_only(&self) -> usize {
        self.n_instrs() * 5
    }

    /// Full accounting including RF constant preloads.
    pub fn size_bytes_total(&self) -> Result<usize, ContextError> {
        Ok(self.words()?.len() * 5)
    }

    /// Cycles to clock the context in (one word per cycle down the
    /// daisy chain).
    pub fn load_cycles(&self) -> Result<usize, ContextError> {
        Ok(self.words()?.len())
    }

    /// Context switch time in microseconds at the given clock.
    pub fn switch_time_us(&self, freq_mhz: f64) -> Result<f64, ContextError> {
        Ok(self.load_cycles()? as f64 / freq_mhz)
    }

    /// Serialize as a packed 40-bit little-endian bit stream.
    pub fn to_bytes(&self) -> Result<Vec<u8>, ContextError> {
        let mut w = BitWriter::new();
        for word in self.words()? {
            w.push(word.as_u64(), 40);
        }
        Ok(w.into_bytes())
    }

    /// Reconstruct per-FU contents from a packed stream (the inverse of
    /// [`Self::to_bytes`]; used by tests and the config-port simulator).
    pub fn from_bytes(kernel: &str, n_fus: usize, bytes: &[u8]) -> Result<Self, ContextError> {
        let mut img = ContextImage::new(kernel, n_fus);
        let mut r = BitReader::new(bytes);
        let mut idx = 0usize;
        while r.remaining_bits() >= 40 {
            let w = ContextWord::from_u64(r.read(40).ok_or(ContextError::Truncated)?);
            let fu = w.fu_index() as usize;
            if fu >= n_fus {
                return Err(ContextError::BadKind(idx, w.tag));
            }
            match w.kind() {
                KIND_INSTR => img.fus[fu].instrs.push(FuInstr::decode(w.payload)?),
                KIND_CONST => img.fus[fu].consts.push(w.payload as i32),
                k => return Err(ContextError::BadKind(idx, k)),
            }
            idx += 1;
        }
        img.validate()?;
        Ok(img)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dfg::OpKind;

    fn demo_image() -> ContextImage {
        let mut img = ContextImage::new("demo", 2);
        img.fus[0].instrs = vec![
            FuInstr::Arith {
                op: OpKind::Sub,
                rs1: 0,
                rs2: 2,
            },
            FuInstr::Bypass { rs: 1 },
        ];
        img.fus[0].consts = vec![42, -7];
        img.fus[1].instrs = vec![FuInstr::Arith {
            op: OpKind::Mul,
            rs1: 0,
            rs2: 0,
        }];
        img
    }

    #[test]
    fn word_tag_fields() {
        let w = ContextWord::rf_const(5, -1);
        assert_eq!(w.fu_index(), 5);
        assert_eq!(w.kind(), KIND_CONST);
        assert_eq!(w.payload, u32::MAX);
        assert_eq!(ContextWord::from_u64(w.as_u64()), w);
    }

    #[test]
    fn words_are_daisy_chain_ordered() {
        let img = demo_image();
        let words = img.words().unwrap();
        // FU1's words first (farthest down the chain).
        assert_eq!(words[0].fu_index(), 1);
        assert_eq!(words.last().unwrap().fu_index(), 0);
        assert_eq!(words.len(), 5);
    }

    #[test]
    fn size_accounting() {
        let img = demo_image();
        assert_eq!(img.size_bytes_instr_only(), 3 * 5);
        assert_eq!(img.size_bytes_total().unwrap(), 5 * 5);
        assert_eq!(img.load_cycles().unwrap(), 5);
    }

    #[test]
    fn switch_time_matches_paper_model() {
        // Paper: worst case 82 words at 300 MHz = 0.27 us.
        let mut img = ContextImage::new("worst", 16);
        let mut left = 82usize;
        'outer: for fu in 0..16 {
            for _ in 0..6 {
                if left == 0 {
                    break 'outer;
                }
                img.fus[fu].instrs.push(FuInstr::Bypass { rs: 0 });
                left -= 1;
            }
        }
        assert_eq!(img.load_cycles().unwrap(), 82);
        let t = img.switch_time_us(300.0).unwrap();
        assert!((t - 0.2733).abs() < 0.001, "t = {t}");
    }

    #[test]
    fn byte_stream_round_trips() {
        let img = demo_image();
        let bytes = img.to_bytes().unwrap();
        assert_eq!(bytes.len(), 25); // 5 words * 40 bits = 200 bits
        let back = ContextImage::from_bytes("demo", 2, &bytes).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn validates_im_capacity() {
        let mut img = ContextImage::new("over", 1);
        img.fus[0].instrs = vec![FuInstr::Bypass { rs: 0 }; 33];
        assert!(matches!(img.validate(), Err(ContextError::ImOverflow(0))));
    }

    #[test]
    fn config_time_of_8fu_pipeline_matches_paper() {
        // Paper §III.A: full 8-FU pipeline with all 32 IM entries used
        // loads in 0.85 us at 300 MHz.
        let mut img = ContextImage::new("full", 8);
        for fu in &mut img.fus {
            fu.instrs = vec![FuInstr::Bypass { rs: 0 }; 32];
        }
        let t = img.switch_time_us(300.0).unwrap();
        assert!((t - 0.8533).abs() < 0.01, "t = {t}");
    }
}
