//! Cross-module integration + property tests.
//!
//! The headline property: for *arbitrary* well-formed kernels (randomly
//! generated sources, not just the benchmark suite), the whole chain
//!   frontend → scheduler → context encode/decode → cycle-accurate
//!   pipeline (both FU variants)
//! agrees with direct DFG evaluation, and the measured II matches the
//! analytical model.

use tmfu_overlay::arch::{config_port, fu_db, Pipeline, PipelineDb};
use tmfu_overlay::dfg::{dfg_from_json, dfg_to_json, eval, eval_batch, Characteristics};
use tmfu_overlay::frontend;
use tmfu_overlay::isa::FuInstr;
use tmfu_overlay::sched::{program_to_json, Program, Timing};
use tmfu_overlay::util::prng::Rng;
use tmfu_overlay::util::quickcheck::{check, gen_i64, gen_vec, prop_assert, Gen};

// ---------------------------------------------------------------------
// Random kernel generation
// ---------------------------------------------------------------------

/// Generate a random well-formed kernel source: straight-line code over
/// n inputs with arithmetic ops, constants and reuse.
fn random_kernel_source(rng: &mut Rng, id: usize) -> String {
    let n_in = 1 + rng.index(6);
    let n_stmts = 3 + rng.index(24);
    let params: Vec<String> = (0..n_in).map(|i| format!("x{i}")).collect();
    let mut vars: Vec<String> = params.clone();
    let mut body = String::new();
    let ops = ["+", "-", "*", "&", "|", "^"];
    for s in 0..n_stmts {
        let name = format!("t{s}");
        let a = rng.choose(&vars).clone();
        let op_space = if rng.chance(0.7) { 3 } else { 6 };
        let op = ops[rng.index(op_space)];
        let rhs = if rng.chance(0.3) {
            format!("{}", rng.range_i64(-64, 64))
        } else {
            rng.choose(&vars).clone()
        };
        body.push_str(&format!("  {name} = {a} {op} {rhs};\n"));
        vars.push(name);
    }
    let ret = vars.last().unwrap().clone();
    format!(
        "kernel rand{id}({}) {{\n{body}  return {ret};\n}}",
        params.join(", ")
    )
}

/// Fuzz: the full compile→simulate chain vs the functional oracle, for
/// both the single-bank and double-buffered pipelines.
#[test]
fn fuzz_full_chain_against_oracle() {
    let mut rng = Rng::new(0xF00D);
    let mut tested = 0;
    for case in 0..60 {
        let src = random_kernel_source(&mut rng, case);
        let g = match frontend::compile(&src) {
            Ok(g) => g,
            Err(e) => panic!("generated source failed to compile: {e}\n{src}"),
        };
        // Normalization may fold everything to a constant; the overlay
        // needs at least one op.
        if g.n_ops() == 0 {
            continue;
        }
        let p = match Program::schedule(&g) {
            Ok(p) => p,
            // RF/IM overflow is a legal outcome for oversized kernels;
            // the error must be clean, not a panic.
            Err(e) => {
                let msg = format!("{e}");
                assert!(
                    msg.contains("overflow"),
                    "unexpected scheduling failure: {msg}\n{src}"
                );
                continue;
            }
        };
        p.check_dataflow().unwrap();
        let n_in = g.inputs().len();
        let packets: Vec<Vec<i32>> = (0..5)
            .map(|_| (0..n_in).map(|_| rng.range_i64(-10_000, 10_000) as i32).collect())
            .collect();
        // The flat batch oracle (row-major in, row-major out).
        let flat: Vec<i32> = packets.iter().flatten().copied().collect();
        let n_out = g.outputs().len();
        let want: Vec<Vec<i32>> = eval_batch(&g, &flat)
            .chunks(n_out)
            .map(<[i32]>::to_vec)
            .collect();
        for (pkt, w) in packets.iter().zip(&want) {
            assert_eq!(w, &eval(&g, pkt), "flat eval_batch diverged from eval");
        }

        let mut pl = Pipeline::new(&p, 4096).unwrap();
        let got = pl.run(&packets, 100_000).unwrap();
        assert_eq!(got, want, "single-bank diverged on case {case}\n{src}");

        let mut pldb = PipelineDb::new(&p, 4096).unwrap();
        let got_db = pldb.run(&packets, 100_000).unwrap();
        assert_eq!(got_db, want, "double-buffered diverged on case {case}\n{src}");

        // II models hold on random kernels too.
        let t = Timing::of(&p);
        let mut pl2 = Pipeline::new(&p, 65536).unwrap();
        let sample: Vec<Vec<i32>> = (0..8).map(|k| vec![k as i32; n_in]).collect();
        let ii = pl2.measure_ii(&sample).unwrap();
        assert!((ii - t.ii as f64).abs() < 1e-9, "case {case}: II {ii} vs {}\n{src}", t.ii);
        assert!(fu_db::ii_double_buffered(&p) <= t.ii, "case {case}");
        tested += 1;
    }
    assert!(tested >= 40, "only {tested} cases exercised");
}

/// Context images survive encode→bytes→decode→daisy-chain load for
/// random kernels.
#[test]
fn fuzz_context_round_trip() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..40 {
        let src = random_kernel_source(&mut rng, 1000 + case);
        let Ok(g) = frontend::compile(&src) else { continue };
        if g.n_ops() == 0 {
            continue;
        }
        let Ok(p) = Program::schedule(&g) else { continue };
        let img = p.context_image().unwrap();
        let bytes = img.to_bytes().unwrap();
        let back =
            tmfu_overlay::isa::ContextImage::from_bytes(&img.kernel, img.n_fus(), &bytes).unwrap();
        assert_eq!(back, img, "case {case}");
        let loaded = config_port::load_image(&img).unwrap();
        assert_eq!(loaded.cycles as usize, img.load_cycles().unwrap());
    }
}

/// DFG JSON and schedule JSON round-trip and stay evaluable.
#[test]
fn fuzz_json_round_trip() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..40 {
        let src = random_kernel_source(&mut rng, 2000 + case);
        let Ok(g) = frontend::compile(&src) else { continue };
        let j = dfg_to_json(&g);
        let g2 = dfg_from_json(&j).unwrap();
        assert_eq!(g, g2);
        let inputs: Vec<i32> = (0..g.inputs().len()).map(|i| i as i32 * 7 - 3).collect();
        assert_eq!(eval(&g, &inputs), eval(&g2, &inputs));
        if g.n_ops() > 0 {
            if let Ok(p) = Program::schedule(&g) {
                let pj = program_to_json(&g, &p);
                // Parse back through the generic JSON parser.
                let text = pj.to_string_pretty();
                let parsed = tmfu_overlay::util::json::parse(&text).unwrap();
                assert_eq!(
                    parsed.get("schedule").get("ii").as_i64(),
                    Some(Timing::of(&p).ii as i64)
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Property tests with the quickcheck harness
// ---------------------------------------------------------------------

/// Instruction encode/decode is a bijection over valid instructions.
#[test]
fn prop_instr_encode_decode() {
    struct GenInstr;
    impl Gen for GenInstr {
        type Value = (u8, u8, u8, bool);
        fn generate(&self, rng: &mut Rng) -> Self::Value {
            (
                rng.index(6) as u8,
                rng.index(32) as u8,
                rng.index(32) as u8,
                rng.chance(0.2),
            )
        }
    }
    check(300, GenInstr, "instr-roundtrip", |&(op_i, rs1, rs2, byp)| {
        let ins = if byp {
            FuInstr::Bypass { rs: rs1 }
        } else {
            FuInstr::Arith {
                op: tmfu_overlay::dfg::OpKind::ALL[op_i as usize],
                rs1,
                rs2,
            }
        };
        let w = ins.encode().map_err(|e| e.to_string())?;
        let back = FuInstr::decode(w).map_err(|e| e.to_string())?;
        prop_assert(back == ins, "decode(encode(i)) != i")
    });
}

/// The II model is monotone: adding a packet's worth of work to a stage
/// can only increase the II (checked over the benchmark suite under
/// input permutations — the schedule is invariant to data values).
#[test]
fn prop_ii_at_least_bottleneck() {
    for name in tmfu_overlay::bench_suite::all_names() {
        let g = tmfu_overlay::bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        let t = Timing::of(&p);
        for st in &p.stages {
            assert!(
                t.ii as usize >= st.cost() + 2,
                "{name}: II {} < stage {} cost {}",
                t.ii,
                st.stage,
                st.cost()
            );
        }
        // And the bottleneck is tight.
        let max_cost = p.stages.iter().map(|s| s.cost()).max().unwrap();
        assert_eq!(t.ii as usize, max_cost + 2, "{name}");
    }
}

/// Wrapping arithmetic: DFG evaluation is invariant under evaluation
/// order (the oracle) vs the staged pipeline for adversarial values.
#[test]
fn prop_extreme_values_bitexact() {
    check(
        60,
        gen_vec(gen_i64(i32::MIN as i64, i32::MAX as i64), 3, 3),
        "poly6-extremes",
        |vals| {
            let g = tmfu_overlay::bench_suite::load("poly6").unwrap();
            let p = Program::schedule(&g).unwrap();
            let packet: Vec<i32> = vals.iter().map(|&v| v as i32).collect();
            let want = eval(&g, &packet);
            let mut pl = Pipeline::new(&p, 1024).map_err(|e| e.to_string())?;
            let got = pl.run(&[packet], 10_000).map_err(|e| e.to_string())?;
            prop_assert(got[0] == want, "pipeline diverged from oracle")
        },
    );
}

/// Characteristics are stable under re-normalization (idempotence).
#[test]
fn prop_normalize_idempotent_on_benchmarks() {
    for name in tmfu_overlay::bench_suite::all_names() {
        let g = tmfu_overlay::bench_suite::load(name).unwrap();
        let n1 = tmfu_overlay::dfg::normalize(&g);
        assert_eq!(g, n1, "{name}: loaded kernels must already be normal forms");
        let c1 = Characteristics::of(&g);
        let c2 = Characteristics::of(&n1);
        assert_eq!(c1, c2);
    }
}

/// Serving-layer oracle equivalence: the interpreter backend and the
/// cycle-accurate simulator backend produce identical outputs for
/// every benchmark kernel on random batches (full wrapping-i32 range).
/// This is the property that makes the backends interchangeable behind
/// the service engine.
#[test]
fn prop_backend_equivalence_ref_vs_sim() {
    use tmfu_overlay::exec::{Backend, FlatBatch, KernelRegistry, RefBackend, SimBackend};
    let reg = KernelRegistry::compile_bench_suite().unwrap();
    for name in tmfu_overlay::bench_suite::all_names() {
        let kernel = reg.get(name).unwrap().clone();
        let n_in = kernel.n_inputs;
        check(
            25,
            gen_vec(gen_i64(i32::MIN as i64, i32::MAX as i64), n_in, n_in * 4),
            &format!("backend-equiv-{name}"),
            |vals| {
                // Interpret the flat value vector as whole packets.
                let whole = vals.len() / n_in * n_in;
                if whole == 0 {
                    return Ok(());
                }
                let mut batch = FlatBatch::with_capacity(n_in, whole / n_in);
                for row in vals[..whole].chunks_exact(n_in) {
                    batch.push_iter(row.iter().map(|&v| v as i32));
                }
                let mut rb = RefBackend::new();
                let mut sb = SimBackend::new(1, 4096).map_err(|e| e.to_string())?;
                let r = rb.execute(&kernel, &batch).map_err(|e| e.to_string())?;
                let s = sb.execute(&kernel, &batch).map_err(|e| e.to_string())?;
                prop_assert(
                    r.outputs == s.outputs,
                    "cycle-accurate sim diverged from the interpreter",
                )
            },
        );
    }
}

/// PR 2 oracle edge: the tape-compiled turbo backend must be
/// bit-identical to the interpreter across the full benchmark suite on
/// full-range wrapping batches — including the adversarial corners
/// (`i32::MIN` propagation, `(1 << 17)²` multiply wraparound) that are
/// seeded into every case alongside the random rows.
#[test]
fn prop_backend_equivalence_ref_vs_turbo() {
    use tmfu_overlay::exec::{Backend, FlatBatch, KernelRegistry, RefBackend, TurboBackend, LANES};
    let reg = KernelRegistry::compile_bench_suite().unwrap();
    for name in tmfu_overlay::bench_suite::all_names() {
        let kernel = reg.get(name).unwrap().clone();
        let n_in = kernel.n_inputs;
        // Batch lengths straddle the lane-chunk boundary so partial
        // chunks are exercised on every kernel.
        check(
            25,
            gen_vec(gen_i64(i32::MIN as i64, i32::MAX as i64), 0, n_in * (LANES + 3)),
            &format!("backend-equiv-turbo-{name}"),
            |vals| {
                let mut batch = FlatBatch::new(n_in);
                // Deterministic wrapping edges ride along in every case.
                batch.push_iter((0..n_in).map(|_| i32::MIN));
                batch.push_iter((0..n_in).map(|_| 1 << 17));
                batch.push_iter((0..n_in).map(|i| if i % 2 == 0 { i32::MAX } else { -1 }));
                let whole = vals.len() / n_in * n_in;
                for row in vals[..whole].chunks_exact(n_in) {
                    batch.push_iter(row.iter().map(|&v| v as i32));
                }
                let mut rb = RefBackend::new();
                let mut tb = TurboBackend::new();
                let r = rb.execute(&kernel, &batch).map_err(|e| e.to_string())?;
                let t = tb.execute(&kernel, &batch).map_err(|e| e.to_string())?;
                prop_assert(
                    r.outputs == t.outputs,
                    "turbo tape diverged from the interpreter",
                )
            },
        );
    }
}

/// Turbo equivalence on *arbitrary* kernels, not just the suite: fuzzed
/// sources go through frontend -> CompiledKernel (schedule + tape) and
/// the tape must agree with the oracle — including squares of 1 << 17
/// and i32::MIN, the multiply/add wraparound corners.
///
/// `TMFU_FUZZ_CASES` scales the case count: CI reruns this in release
/// mode with a raised count so the SIMD lane kernels — which only
/// exist under optimization — face the oracle in the codegen mode
/// users actually run.
#[test]
fn fuzz_turbo_tape_against_oracle() {
    use tmfu_overlay::exec::{Backend, CompiledKernel, FlatBatch, TurboBackend};
    let cases: usize = std::env::var("TMFU_FUZZ_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let mut rng = Rng::new(0x7EA7);
    let mut tested = 0;
    for case in 0..cases {
        let src = random_kernel_source(&mut rng, 3000 + case);
        let Ok(g) = frontend::compile(&src) else { continue };
        if g.n_ops() == 0 {
            continue;
        }
        let kernel = match CompiledKernel::compile(g) {
            Ok(k) => k,
            Err(e) => {
                let msg = format!("{e}");
                assert!(msg.contains("overflow"), "unexpected compile failure: {msg}\n{src}");
                continue;
            }
        };
        let n_in = kernel.n_inputs;
        let mut batch = FlatBatch::new(n_in);
        batch.push_iter((0..n_in).map(|_| i32::MIN));
        batch.push_iter((0..n_in).map(|_| 1 << 17));
        for _ in 0..21 {
            batch.push_iter((0..n_in).map(|_| rng.next_i32()));
        }
        let want: Vec<Vec<i32>> = batch.iter().map(|p| eval(&kernel.dfg, p)).collect();
        let mut tb = TurboBackend::new();
        let t = tb.execute(&kernel, &batch).unwrap();
        assert_eq!(t.outputs.to_rows(), want, "case {case} diverged\n{src}");
        tested += 1;
    }
    // Oversized kernels legitimately fail to schedule; require the
    // same ~60% hit rate the default 50-case run has always met.
    let floor = cases * 3 / 5;
    assert!(tested >= floor, "only {tested}/{cases} cases exercised (floor {floor})");
}

/// End-to-end spot check: the same workload served through a turbo
/// service and a sim service returns identical, oracle-exact results
/// (the serving-layer closure of the three-oracle chain). Sessions are
/// pre-resolved `KernelHandle`s — no name lookups inside the loop.
#[test]
fn turbo_vs_sim_spot_check_through_service() {
    use tmfu_overlay::exec::BackendKind;
    use tmfu_overlay::service::OverlayService;
    let mk = |kind| {
        OverlayService::builder()
            .backend(kind)
            .pipelines(2)
            .max_batch(16)
            .build()
            .unwrap()
    };
    let turbo = mk(BackendKind::Turbo);
    let sim = mk(BackendKind::Sim);
    let turbo_handles = turbo.handles();
    let sim_handles = sim.handles();
    let mut rng = Rng::new(77);
    let mut jobs = Vec::new();
    for i in 0..48 {
        let ht = &turbo_handles[i % turbo_handles.len()];
        let hs = &sim_handles[i % sim_handles.len()];
        assert_eq!(ht.name(), hs.name(), "registries must agree on id order");
        let inputs: Vec<i32> = (0..ht.arity())
            .map(|_| rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32)
            .collect();
        let want = eval(&ht.compiled().dfg, &inputs);
        jobs.push((ht.submit(&inputs).unwrap(), hs.submit(&inputs).unwrap(), want));
    }
    for (pt, ps, want) in jobs {
        let got_t = pt.wait().unwrap();
        let got_s = ps.wait().unwrap();
        assert_eq!(got_t, want, "turbo diverged from oracle");
        assert_eq!(got_s, got_t, "sim and turbo services disagree");
    }
    turbo.shutdown().unwrap();
    sim.shutdown().unwrap();
}

/// Service-layer transparency property: for every benchmark kernel,
/// `KernelHandle::call` / `call_batch` through a live `OverlayService`
/// return exactly what a directly-constructed backend returns for the
/// same batch — the service adds queueing, batching and sessions, but
/// never changes results. Checked on the ref and turbo substrates,
/// plus the lifecycle edges: submit-after-shutdown and a deterministic
/// admission rejection.
#[test]
fn prop_service_equivalence() {
    use tmfu_overlay::exec::{make_backend, Backend, BackendKind, FlatBatch};
    use tmfu_overlay::service::{OverlayService, ServiceError};

    for kind in [BackendKind::Ref, BackendKind::Turbo] {
        let service = OverlayService::builder()
            .backend(kind)
            .pipelines(2)
            .max_batch(8)
            .build()
            .unwrap();
        let mut direct = make_backend(kind, std::path::Path::new("artifacts"), 1, 4096).unwrap();
        let mut rng = Rng::new(0x5E4 + kind.name().len() as u64);
        for h in service.handles() {
            let kernel = h.compiled().clone();
            let mut batch = FlatBatch::new(h.arity());
            // Wrapping corners ride along with the random rows.
            batch.push_iter((0..h.arity()).map(|_| i32::MIN));
            batch.push_iter((0..h.arity()).map(|_| 1 << 17));
            for _ in 0..19 {
                batch.push_iter((0..h.arity()).map(|_| rng.next_i32()));
            }
            let want = direct.execute(&kernel, &batch).unwrap().outputs;
            // Whole-batch call: row order and values are preserved.
            let got = h.call_batch(&batch).unwrap();
            assert_eq!(got, want, "{} ({kind}) call_batch diverged", h.name());
            // Per-row calls agree with the batch rows.
            for (i, row) in batch.iter().enumerate().step_by(7) {
                assert_eq!(
                    h.call(row).unwrap(),
                    want.row(i).to_vec(),
                    "{} ({kind}) call diverged on row {i}",
                    h.name()
                );
            }
        }
        service.shutdown().unwrap();
    }

    // Lifecycle edge 1: handles outlive the service value, and work
    // submitted after shutdown gets the typed shutdown error.
    let service = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .build()
        .unwrap();
    let h = service.kernel("gradient").unwrap();
    service.shutdown().unwrap();
    assert_eq!(h.call(&[1, 2, 3, 4, 5]).unwrap_err(), ServiceError::ShutDown);
    assert_eq!(h.submit(&[1, 2, 3, 4, 5]).unwrap_err(), ServiceError::ShutDown);

    // Lifecycle edge 2: a batch wider than the configured queue depth
    // is deterministically refused by admission control and counted in
    // the metrics snapshot.
    let service = OverlayService::builder()
        .backend(BackendKind::Ref)
        .queue_depth(4)
        .build()
        .unwrap();
    let h = service.kernel("gradient").unwrap();
    let rows: Vec<Vec<i32>> = (0..5).map(|i| vec![i; 5]).collect();
    let batch = FlatBatch::from_rows(5, &rows);
    match h.call_batch(&batch).unwrap_err() {
        ServiceError::Rejected { queued, limit, .. } => {
            assert_eq!(limit, 4);
            assert!(queued <= 4);
        }
        other => panic!("expected Rejected, got {other}"),
    }
    assert_eq!(service.metrics().rejected, 5);
    service.shutdown().unwrap();
}

/// Completion-slab stress: several threads hammer one service with
/// every client pattern at once — blocking waits, polls, deadline
/// waits racing the workers, and `Pending`s dropped without ever
/// being collected — while the service is shut down out from under
/// them. Pins down the slab invariants: no lost wakeups (every wait
/// returns), no stale-generation reads (every collected result is
/// oracle-exact, so a recycled slot can never leak another request's
/// reply), and the admission ledger stays consistent
/// (`admitted == completed + failed`) even with abandoned replies.
#[test]
fn slab_stress_under_concurrent_shutdown() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};
    use tmfu_overlay::exec::BackendKind;
    use tmfu_overlay::service::{OverlayService, ServiceError};

    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(3)
            .max_batch(16)
            .queue_depth(100_000)
            .build()
            .unwrap(),
    );
    let handle = service.kernel("gradient").unwrap();
    let admitted = Arc::new(AtomicU64::new(0));
    let mut threads = Vec::new();
    for t in 0..6i32 {
        let h = handle.clone();
        let dfg = handle.compiled().dfg.clone();
        let admitted = Arc::clone(&admitted);
        threads.push(std::thread::spawn(move || {
            for i in 0..400i32 {
                let inputs = [t, i, 2, 7, t - i];
                let want = eval(&dfg, &inputs);
                let mut p = match h.submit(&inputs) {
                    Ok(p) => p,
                    // The main thread shuts the service down mid-run.
                    Err(ServiceError::ShutDown) => continue,
                    Err(e) => panic!("unexpected submit error: {e}"),
                };
                admitted.fetch_add(1, Ordering::SeqCst);
                match i % 4 {
                    // Blocking wait: must return the oracle row.
                    0 => assert_eq!(p.wait().unwrap(), want),
                    // Drop without waiting: the slot must recycle via
                    // the abandon path, whether the worker has run yet
                    // or not.
                    1 => drop(p),
                    // Poll a few times, then maybe drop mid-flight.
                    2 => {
                        for _ in 0..3 {
                            if let Some(r) = p.poll() {
                                assert_eq!(r.unwrap(), want);
                                break;
                            }
                            std::thread::yield_now();
                        }
                    }
                    // A deadline wait racing completion; on timeout
                    // the request stays in flight and a later wait
                    // must still produce the reply (drain semantics
                    // guarantee it even after shutdown).
                    _ => {
                        let soon = Instant::now() + Duration::from_micros(50);
                        match p.wait_deadline(soon) {
                            Ok(got) => assert_eq!(got, want),
                            Err(ServiceError::DeadlineExceeded { .. }) => {
                                let got = p.wait_timeout(Duration::from_secs(60)).unwrap();
                                assert_eq!(got, want);
                            }
                            Err(e) => panic!("unexpected wait error: {e}"),
                        }
                    }
                }
            }
        }));
    }
    // Fire shutdown while the submitters are mid-flight. Drain
    // semantics: everything admitted before the flag still completes.
    std::thread::sleep(Duration::from_millis(10));
    service.shutdown().unwrap();
    for t in threads {
        t.join().unwrap();
    }
    let snap = service.metrics();
    assert_eq!(snap.failed, 0, "no request may fail in this workload");
    assert_eq!(
        snap.completed + snap.failed,
        admitted.load(Ordering::SeqCst),
        "admission ledger drifted: every admitted request must be \
         completed or failed exactly once, abandoned or not"
    );
    // Idempotent: a second shutdown finds nothing left to do.
    service.shutdown().unwrap();
}

/// Cross-worker batch splitting is invisible to clients: a batch whose
/// row count is not a multiple of the SIMD lane width (16), the chunk
/// width (8) or the split width (`max_batch`) fans out across workers
/// as row spans and recombines in the completion slab bit-exactly —
/// same rows, same order — as the unsplit direct-backend run, for
/// every benchmark kernel (wrapping corners seeded into each batch).
#[test]
fn split_batches_recombine_bit_exactly() {
    use tmfu_overlay::exec::{make_backend, Backend, BackendKind, FlatBatch};
    use tmfu_overlay::service::OverlayService;

    // 131 is prime: no alignment with LANES (16), the chunk width (8)
    // or the 5-row split width, so span boundaries land mid-chunk.
    const ROWS: usize = 131;
    let service = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .pipelines(4)
        .max_batch(5)
        .queue_depth(4 * ROWS)
        .build()
        .unwrap();
    let mut direct =
        make_backend(BackendKind::Turbo, std::path::Path::new("artifacts"), 1, 4096).unwrap();
    let mut rng = Rng::new(0x51D);
    for h in service.handles() {
        let kernel = h.compiled().clone();
        let mut batch = FlatBatch::new(h.arity());
        batch.push_iter((0..h.arity()).map(|_| i32::MIN));
        batch.push_iter((0..h.arity()).map(|_| 1 << 17));
        for _ in 0..ROWS - 2 {
            batch.push_iter((0..h.arity()).map(|_| rng.next_i32()));
        }
        let want = direct.execute(&kernel, &batch).unwrap().outputs;
        let got = h.call_batch(&batch).unwrap();
        assert_eq!(got.n_rows(), ROWS, "{}: row count changed in flight", h.name());
        assert_eq!(got, want, "{}: split batch recombined differently", h.name());
    }
    service.shutdown().unwrap();
}

/// The split path keeps the admission ledger exact under shutdown:
/// batches admitted before the flag drain (possibly as several spans
/// on different workers), abandoned `PendingBatch`es recycle their
/// slots, and `admitted == completed + failed` holds to the row.
#[test]
fn split_batch_ledger_survives_concurrent_shutdown() {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::Duration;
    use tmfu_overlay::exec::{BackendKind, FlatBatch};
    use tmfu_overlay::service::{OverlayService, ServiceError};

    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(3)
            .max_batch(7)
            .queue_depth(100_000)
            .build()
            .unwrap(),
    );
    let handle = service.kernel("gradient").unwrap();
    let admitted = Arc::new(AtomicU64::new(0));
    let mut threads = Vec::new();
    for t in 0..4i32 {
        let h = handle.clone();
        let dfg = handle.compiled().dfg.clone();
        let admitted = Arc::clone(&admitted);
        threads.push(std::thread::spawn(move || {
            for i in 0..120i32 {
                // Row counts sweep 1..=40 — never aligned with the
                // 7-row split width or the 16-lane chunks.
                let rows = 1 + ((t * 13 + i * 7) % 40) as usize;
                let mut batch = FlatBatch::new(5);
                for r in 0..rows {
                    batch.push_iter([t, i, r as i32, 7, t - i].into_iter());
                }
                let p = match h.submit_batch(&batch) {
                    Ok(p) => p,
                    // The main thread shuts the service down mid-run;
                    // admission is all-or-nothing per batch.
                    Err(ServiceError::ShutDown) => continue,
                    Err(e) => panic!("unexpected submit error: {e}"),
                };
                admitted.fetch_add(rows as u64, Ordering::SeqCst);
                if i % 3 == 0 {
                    // Abandon mid-flight: the slot must recycle and
                    // the rows still land in the completed counter.
                    drop(p);
                } else {
                    let got = p.wait().unwrap();
                    assert_eq!(got.n_rows(), rows);
                    for (r, row) in batch.iter().enumerate() {
                        assert_eq!(
                            got.row(r),
                            eval(&dfg, row).as_slice(),
                            "row {r} diverged from the oracle"
                        );
                    }
                }
            }
        }));
    }
    // Fire shutdown while the batch submitters are mid-flight.
    std::thread::sleep(Duration::from_millis(5));
    service.shutdown().unwrap();
    for th in threads {
        th.join().unwrap();
    }
    let snap = service.metrics();
    assert_eq!(snap.failed, 0, "no request may fail in this workload");
    assert_eq!(
        snap.completed + snap.failed,
        admitted.load(Ordering::SeqCst),
        "split-batch admission ledger drifted under shutdown"
    );
    service.shutdown().unwrap();
}

/// Full-suite smoke of the CLI-facing report renderers (they are the
/// bench backbone; must never error).
#[test]
fn reports_render() {
    assert!(tmfu_overlay::report::table2::render().unwrap().contains("chebyshev"));
    assert!(tmfu_overlay::report::table3::render().unwrap().contains("headlines"));
    assert!(tmfu_overlay::report::fig5::render().unwrap().contains("reduction"));
    assert!(tmfu_overlay::report::fig6::render().unwrap().contains("geomean"));
    assert!(tmfu_overlay::report::ctx_switch::render().unwrap().contains("speedup"));
    assert!(tmfu_overlay::report::resources_report::render().contains("325"));
}

/// The committed interchange JSONs (`benchmarks/dfg/*.json`) must match
/// what the current compiler produces — Python consumes these files, so
/// drift between the Rust scheduler and the committed artifacts would
/// silently desynchronize the layers. Regenerate with
/// `target/release/tmfu export-dfg` when the compiler changes.
#[test]
fn committed_dfg_jsons_are_in_sync() {
    // benchmarks/ lives at the repository root, one level above this
    // package (same convention as bench_suite's include_str! sources
    // and python/compile/dfg.py).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../benchmarks/dfg");
    for name in tmfu_overlay::bench_suite::all_names() {
        let path = dir.join(format!("{name}.json"));
        let committed = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{}: {e} (run `tmfu export-dfg`)", path.display()));
        let g = tmfu_overlay::bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        let current = program_to_json(&g, &p).to_string_pretty();
        assert_eq!(
            committed.trim(),
            current.trim(),
            "{name}: committed DFG JSON is stale — run `tmfu export-dfg`"
        );
    }
}

/// ALAP scheduling: correctness (oracle agreement through the
/// cycle-accurate pipeline) and the design-space comparison vs ASAP.
#[test]
fn alap_schedules_are_correct_and_comparable() {
    use tmfu_overlay::dfg::Levels;
    let mut improved_ctx = 0usize;
    for name in tmfu_overlay::bench_suite::all_names() {
        let g = tmfu_overlay::bench_suite::load(name).unwrap();
        let asap = Program::schedule(&g).unwrap();
        let alap = Program::schedule_alap(&g).unwrap();
        alap.check_dataflow().unwrap();
        assert_eq!(asap.n_fus(), alap.n_fus(), "{name}: depth must not change");
        // Sanity: ALAP levels respect dependencies.
        let levels = Levels::alap(&g);
        for id in 0..g.len() as u32 {
            let n = g.node(id);
            if n.is_op() {
                for &a in &n.args {
                    assert!(
                        levels.level[a as usize] < levels.level[id as usize],
                        "{name}: dependency violated"
                    );
                }
            }
        }
        // Correctness through the cycle-accurate pipeline.
        let packets: Vec<Vec<i32>> = (0..4)
            .map(|k| (0..g.inputs().len()).map(|i| (k * 31 + i as i32) - 17).collect())
            .collect();
        let mut pl = Pipeline::new(&alap, 4096).unwrap();
        let got = pl.run(&packets, 100_000).unwrap();
        for (pkt, o) in packets.iter().zip(&got) {
            assert_eq!(o, &eval(&g, pkt), "{name} (ALAP) diverged");
        }
        // Design-space comparison: context sizes.
        let ctx_asap = asap.context_image().unwrap().size_bytes_instr_only();
        let ctx_alap = alap.context_image().unwrap().size_bytes_instr_only();
        if ctx_alap < ctx_asap {
            improved_ctx += 1;
        }
    }
    // ALAP shortens bypass chains on some benchmarks; it must never be
    // catastrophically worse — checked per-kernel above via II? keep a
    // weak global assertion here (the ablation bench prints the table).
    let _ = improved_ctx;
}
