//! Tenant-authentication negative paths (DESIGN.md §13, PROTOCOL.md
//! "Tenant authentication"): every malformed, unsigned, or replayed
//! Hello against an auth-required server must yield one documented
//! typed error followed by a hangup — never a panic, never a wedge —
//! and the server must stay healthy for the next connection.
//!
//! The raw-socket cases handcraft Hello frames with `write_frame` (or
//! splice bytes directly for the truncation case) because the real
//! client never produces these: it signs fresh nonces and never
//! truncates. The positive path — a correctly signed client against
//! the same server — runs last over the same listener to prove the
//! rejections left nothing poisoned.

use std::io::Write as _;
use std::sync::Arc;
use tmfu_overlay::client::OverlayClient;
use tmfu_overlay::exec::BackendKind;
use tmfu_overlay::service::{OverlayService, ServiceError};
use tmfu_overlay::wire::auth::TenantKeyring;
use tmfu_overlay::wire::server::{ServerCtl, WireServer};
use tmfu_overlay::wire::{read_frame, write_frame, Frame, ListenAddr, TenantToken, WireError};

const SECRET: &[u8] = b"opensesame";

/// An auth-required server: two tenants in the keyring, each with its
/// own service lane.
fn start_auth_server() -> (Arc<OverlayService>, WireServer, String) {
    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(2)
            .max_batch(8)
            .queue_depth(256)
            .tenant("acme")
            .tenant("rival")
            .build()
            .unwrap(),
    );
    let keyring =
        TenantKeyring::parse("acme:opensesame\nrival:hunter2").expect("keyring parses");
    let ctl = ServerCtl::new();
    ctl.set_auth(Arc::new(keyring));
    let server = WireServer::bind_with_ctl(
        Arc::clone(&service),
        &ListenAddr::parse("127.0.0.1:0"),
        None,
        ctl,
    )
    .unwrap();
    let addr = server.addr().to_string();
    (service, server, addr)
}

/// Send one handcrafted Hello and expect a typed Unauthorized error
/// whose message contains `want`, followed by a hangup.
fn expect_unauthorized(addr: &str, hello: &Frame, want: &str) {
    let mut s = std::net::TcpStream::connect(addr).unwrap();
    write_frame(&mut s, hello).unwrap();
    match read_frame(&mut s).unwrap().unwrap() {
        Frame::Error { err, .. } => match err {
            WireError::Unauthorized { message } => {
                assert!(
                    message.contains(want),
                    "expected message containing '{want}', got '{message}'"
                );
            }
            other => panic!("expected Unauthorized, got {other:?}"),
        },
        other => panic!("expected Error frame, got {other:?}"),
    }
    // Hangup, not a wedge: the stream ends after the refusal.
    assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
}

fn signed_hello(tenant: &str, secret: &[u8], nonce: u64) -> Frame {
    Frame::Hello {
        id: 0,
        min: 1,
        max: 2,
        token: Some(TenantToken::sign(tenant, secret, nonce)),
    }
}

#[test]
fn every_bad_hello_is_refused_typed_and_the_server_survives() {
    let (service, server, addr) = start_auth_server();

    // 1. Bad signature: right tenant, wrong secret.
    expect_unauthorized(
        &addr,
        &signed_hello("acme", b"wrong-secret", 1),
        "bad tenant signature",
    );

    // 2. Unknown tenant: a name the keyring has never heard of.
    expect_unauthorized(
        &addr,
        &signed_hello("nonesuch", SECRET, 2),
        "unknown tenant 'nonesuch'",
    );

    // 3. Anonymous Hello against an auth-required server.
    expect_unauthorized(
        &addr,
        &Frame::Hello {
            id: 0,
            min: 1,
            max: 2,
            token: None,
        },
        "requires a tenant token",
    );

    // 4. v1-only client presenting a token: tokens are a v2 feature,
    // and the negotiated version here can only be 1.
    expect_unauthorized(
        &addr,
        &Frame::Hello {
            id: 0,
            min: 1,
            max: 1,
            token: Some(TenantToken::sign("acme", SECRET, 3)),
        },
        "require protocol v2",
    );

    // 5. A plain v1 client (no token at all) is refused the same way
    // an anonymous v2 client is: the server demands a token.
    expect_unauthorized(
        &addr,
        &Frame::Hello {
            id: 0,
            min: 1,
            max: 1,
            token: None,
        },
        "requires a tenant token",
    );

    // 6. Replay: the same signed Hello bytes on a second connection.
    // The first use succeeds; the second is refused by the burned
    // nonce even though the signature itself is valid.
    let replayed = signed_hello("acme", SECRET, 77);
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &replayed).unwrap();
        assert!(matches!(
            read_frame(&mut s).unwrap().unwrap(),
            Frame::HelloOk { version: 2, .. }
        ));
    }
    expect_unauthorized(&addr, &replayed, "replayed tenant nonce");

    // 7. Truncated token: a signed Hello with the tail of its MAC cut
    // off (length prefix adjusted to match, so this is a well-framed
    // message whose *body* is short). The codec refuses it as
    // malformed and the server hangs up.
    {
        let mut buf = Vec::new();
        write_frame(&mut buf, &signed_hello("acme", SECRET, 99)).unwrap();
        let body = &buf[4..buf.len() - 5]; // drop the last 5 MAC bytes
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        // cast-ok: a Hello body is far below u32::MAX bytes.
        s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
        s.write_all(body).unwrap();
        s.flush().unwrap();
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::Error { err, .. } => {
                assert!(
                    matches!(err, WireError::Malformed { .. }),
                    "expected Malformed, got {err:?}"
                );
            }
            other => panic!("expected Error frame, got {other:?}"),
        }
        assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
    }

    // After all that abuse: a correctly signed client connects, calls,
    // and sees its own tenant attributed in the metrics. Nothing about
    // the refused connections leaked into the service.
    let client = OverlayClient::builder()
        .tenant("acme")
        .secret(SECRET)
        .connect(&addr)
        .unwrap();
    assert_eq!(client.version(), 2);
    let gradient = client.kernel("gradient").unwrap();
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);
    let m = client.metrics().unwrap();
    assert_eq!(m.get("per_tenant").get("acme").get("completed").as_i64(), Some(1));
    // The abuse never admitted anything: no rejections, no failures.
    assert_eq!(m.get("rejected").as_i64(), Some(0));
    assert_eq!(m.get("failed").as_i64(), Some(0));

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn wrong_tenant_secret_surfaces_as_a_typed_client_error() {
    let (service, server, addr) = start_auth_server();
    // The real client with bad credentials gets the same typed error a
    // linked-in caller would: Backend { backend: "auth", .. }.
    let err = OverlayClient::builder()
        .tenant("acme")
        .secret(b"guessed-wrong")
        .connect(&addr)
        .unwrap_err();
    match err {
        ServiceError::Backend { backend, message } => {
            assert_eq!(backend, "auth");
            assert!(message.contains("bad tenant signature"), "{message}");
        }
        other => panic!("expected auth backend error, got {other}"),
    }
    // A tenant name with no secret at all signs over empty bytes —
    // also refused, also typed.
    let err = OverlayClient::builder()
        .tenant("acme")
        .connect(&addr)
        .unwrap_err();
    assert!(matches!(err, ServiceError::Backend { ref backend, .. } if backend == "auth"));
    // And the server still serves honest tenants afterwards.
    let client = OverlayClient::builder()
        .tenant("rival")
        .secret(b"hunter2")
        .connect(&addr)
        .unwrap();
    assert_eq!(client.kernel("gradient").unwrap().call(&[1, 1, 1, 1, 1]).unwrap().len(), 1);
    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn auth_off_accepts_tokens_as_attribution_and_anonymous_hellos() {
    // No keyring: anonymous and token-bearing clients both work; the
    // token's tenant name is attribution only (unknown names fall back
    // to the default lane, so traffic still lands in the ledger).
    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(1)
            .max_batch(8)
            .queue_depth(64)
            .build()
            .unwrap(),
    );
    let server =
        WireServer::bind(Arc::clone(&service), &ListenAddr::parse("127.0.0.1:0")).unwrap();
    let addr = server.addr().to_string();

    let anon = OverlayClient::connect(&addr).unwrap();
    assert_eq!(anon.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    let labeled = OverlayClient::builder()
        .tenant("acme")
        .secret(SECRET)
        .connect(&addr)
        .unwrap();
    assert_eq!(
        labeled.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(),
        vec![36]
    );
    // Both calls landed on the default lane (the only one configured).
    let m = labeled.metrics().unwrap();
    assert_eq!(m.get("per_tenant").get("default").get("completed").as_i64(), Some(2));

    drop(anon);
    drop(labeled);
    server.shutdown();
    service.shutdown().unwrap();
}
