//! Multi-tenant fairness, in process (DESIGN.md §13): the weighted
//! deficit-round-robin scheduler and the per-tenant admission quotas,
//! observed through the service API the way a linked-in embedder sees
//! them. Three properties:
//!
//! 1. **Weighted share** — under sustained contention a weight-3 lane
//!    drains about three times the rows of a weight-1 lane
//!    (tolerance-banded: the band is wide because the measurement
//!    races the drain, but the weights are far enough apart that the
//!    signal cannot be mistaken for round-robin).
//! 2. **Quota** — an over-quota tenant gets the typed
//!    [`ServiceError::Rejected`] *with its own name in it*, while a
//!    tenant inside its quota is never rejected.
//! 3. **Isolation** — a polite tenant's tail latency stays bounded
//!    while a greedy tenant floods the service: the polite p99 lands
//!    well under the flooder's own mean, because DRR keeps handing the
//!    polite lane its share per round instead of FIFO-queueing it
//!    behind the backlog.
//!
//! Every test also closes its per-tenant ledger: after a full drain,
//! `admitted == completed + failed` for each tenant separately.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tmfu_overlay::exec::{BackendKind, FlatBatch};
use tmfu_overlay::service::{MetricsSnapshot, OverlayService, ServiceError, TenantMetrics};

const ROW: [i32; 5] = [3, 5, 2, 7, 1]; // gradient(ROW) == 36

fn flood_batch(rows: usize) -> FlatBatch {
    let rows: Vec<Vec<i32>> = (0..rows).map(|_| ROW.to_vec()).collect();
    FlatBatch::from_rows(ROW.len(), &rows)
}

fn tenant<'a>(snap: &'a MetricsSnapshot, name: &str) -> &'a TenantMetrics {
    snap.per_tenant
        .iter()
        .find(|t| t.name == name)
        .unwrap_or_else(|| panic!("tenant '{name}' missing from snapshot"))
}

/// `admitted == completed + failed`, per tenant, once drained.
fn assert_ledger_closed(t: &TenantMetrics) {
    assert_eq!(
        t.admitted,
        t.completed + t.failed,
        "tenant '{}' ledger leaks: admitted {} != completed {} + failed {}",
        t.name,
        t.admitted,
        t.completed,
        t.failed
    );
}

#[test]
fn weighted_tenant_drains_proportionally_and_ledgers_close() {
    // One worker so the DRR pick order is the only drain order; a
    // small row budget so lanes interleave at fine grain.
    let service = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .pipelines(1)
        .max_batch(4)
        .queue_depth(1 << 17)
        .tenant_weight("heavy", 3)
        .tenant_weight("light", 1)
        .build()
        .unwrap();
    let heavy = service.kernel_for("gradient", "heavy").unwrap();
    let light = service.kernel_for("gradient", "light").unwrap();
    assert_eq!(heavy.tenant_name(), "heavy");
    assert_eq!(light.tenant_name(), "light");

    // Enqueue 16384 rows per tenant as 64 interleaved 256-row batches:
    // batch admission is orders of magnitude cheaper than execution,
    // so both lanes are deeply backlogged long before the single
    // worker makes a dent — the drain runs under real contention.
    let batch = flood_batch(256);
    let per_tenant_rows: u64 = 64 * 256;
    let mut pending = Vec::new();
    for _ in 0..64 {
        pending.push(heavy.submit_batch(&batch).unwrap());
        pending.push(light.submit_batch(&batch).unwrap());
    }

    // Snapshot mid-drain: wait (lock-free poll) until a quarter of the
    // rows have completed, then read the per-tenant ledgers. While
    // both lanes are non-empty the drain ratio tracks the 3:1 weights;
    // the heavy lane only runs out around two-thirds of the total, so
    // a quarter-point snapshot observes steady contention.
    let total = per_tenant_rows * 2;
    let deadline = Instant::now() + Duration::from_secs(60);
    while service.completed() < total / 4 {
        assert!(Instant::now() < deadline, "drain stalled");
        std::hint::spin_loop();
    }
    let mid = service.metrics();
    let h = tenant(&mid, "heavy").completed;
    let l = tenant(&mid, "light").completed;
    if h + l < total * 2 / 3 {
        // cast-ok: row counts are far below f64's exact-integer range.
        let ratio = h as f64 / (l as f64).max(1.0);
        assert!(
            (1.5..=6.0).contains(&ratio),
            "weight-3 tenant drained {h} rows vs weight-1's {l} \
             (ratio {ratio:.2}, expected ~3.0 within [1.5, 6.0])"
        );
    } else {
        // The snapshot raced past the contended region (machine much
        // faster than the poll): the weak form must still hold — the
        // heavier tenant can never be behind the lighter one.
        assert!(h >= l, "weight-3 tenant behind weight-1: {h} < {l}");
    }

    // Full drain: every batch replies, every row is oracle-exact.
    for p in pending {
        let out = p.wait().unwrap();
        assert_eq!(out.n_rows(), 256);
        assert_eq!(out.row(0), &[36]);
        assert_eq!(out.row(255), &[36]);
    }

    let snap = service.metrics();
    for name in ["heavy", "light"] {
        let t = tenant(&snap, name);
        assert_eq!(t.admitted, per_tenant_rows, "tenant '{name}'");
        assert_eq!(t.completed, per_tenant_rows, "tenant '{name}'");
        assert_eq!(t.failed, 0, "tenant '{name}'");
        assert_eq!(t.rejected, 0, "tenant '{name}'");
        assert_ledger_closed(t);
        let lat = t.latency_us.as_ref().expect("latency recorded");
        assert_eq!(lat.n, per_tenant_rows as usize, "tenant '{name}'");
    }
    service.shutdown().unwrap();
}

#[test]
fn quota_rejects_the_greedy_tenant_by_name_and_spares_the_polite() {
    let service = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .pipelines(1)
        .max_batch(4)
        .queue_depth(1024)
        .tenant_quota("greedy", 32)
        .tenant("polite")
        .build()
        .unwrap();
    let greedy = service.kernel_for("gradient", "greedy").unwrap();
    let polite = service.kernel_for("gradient", "polite").unwrap();

    // 64 rows against a 32-row quota: atomically refused (batches are
    // all-or-nothing) with the tenant named in the typed error. The
    // lane is empty at this point, so the reported occupancy is 0.
    let err = greedy.submit_batch(&flood_batch(64)).unwrap_err();
    match err {
        ServiceError::Rejected {
            kernel,
            tenant,
            queued,
            limit,
        } => {
            assert_eq!(kernel, "gradient");
            assert_eq!(tenant, "greedy");
            assert_eq!(queued, 0);
            assert_eq!(limit, 32);
        }
        other => panic!("expected Rejected, got {other}"),
    }

    // The same 64 rows are fine for the unlimited polite tenant, and
    // a within-quota greedy batch is fine too: the quota is a bound on
    // the greedy tenant's *own* occupancy, not a penalty flag.
    let polite_out = polite.submit_batch(&flood_batch(64)).unwrap().wait().unwrap();
    assert_eq!(polite_out.n_rows(), 64);
    let greedy_out = greedy.submit_batch(&flood_batch(16)).unwrap().wait().unwrap();
    assert_eq!(greedy_out.n_rows(), 16);

    let snap = service.metrics();
    let g = tenant(&snap, "greedy");
    assert_eq!(g.rejected, 64, "every refused row lands in the ledger");
    assert_eq!(g.admitted, 16);
    assert_eq!(g.completed, 16);
    assert_ledger_closed(g);
    let p = tenant(&snap, "polite");
    assert_eq!(p.rejected, 0, "the polite tenant is never rejected");
    assert_eq!(p.admitted, 64);
    assert_eq!(p.completed, 64);
    assert_ledger_closed(p);
    service.shutdown().unwrap();
}

#[test]
fn polite_tail_latency_stays_bounded_under_a_greedy_flood() {
    // Equal weights: isolation here comes purely from round-robin over
    // lanes, not from a weight advantage.
    let service = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .pipelines(1)
        .max_batch(4)
        .queue_depth(1 << 17)
        .tenant("greedy")
        .tenant("polite")
        .build()
        .unwrap();
    let greedy = service.kernel_for("gradient", "greedy").unwrap();
    let polite = service.kernel_for("gradient", "polite").unwrap();

    // The flood: 16384 rows dumped up front. Every polite call below
    // contends with this backlog (until it drains, after which the
    // late calls only pull the polite percentile *down*).
    let batch = flood_batch(256);
    let pending: Vec<_> = (0..64)
        .map(|_| greedy.submit_batch(&batch).unwrap())
        .collect();

    // The polite tenant: sequential single calls, each a full
    // round trip before the next is sent.
    for _ in 0..200 {
        assert_eq!(polite.call(&ROW).unwrap(), vec![36]);
    }
    for p in pending {
        p.wait().unwrap();
    }

    let snap = service.metrics();
    let g = tenant(&snap, "greedy");
    let p = tenant(&snap, "polite");
    assert_eq!(p.rejected, 0, "the polite tenant is never rejected");
    assert_eq!(g.rejected, 0, "the flood was admitted, not refused");
    assert_eq!(p.completed, 200);
    assert_ledger_closed(g);
    assert_ledger_closed(p);

    // The fairness bound: a polite row waits at most a few DRR rounds
    // (its lane is nearly empty, and each round services it before
    // returning to the flood), while the average flooded row waits out
    // about half its 16k-row backlog. The polite p99 therefore sits
    // far below the greedy *mean*; asserting half the mean keeps a
    // wide margin on slow or noisy machines while still refuting FIFO
    // (under FIFO the polite p99 would exceed the greedy mean).
    let p_lat = p.latency_us.as_ref().expect("polite latency recorded");
    let g_lat = g.latency_us.as_ref().expect("greedy latency recorded");
    assert!(
        p_lat.p99 < g_lat.mean / 2.0,
        "polite p99 {:.1}us not bounded by greedy mean {:.1}us",
        p_lat.p99,
        g_lat.mean
    );
    service.shutdown().unwrap();
}
