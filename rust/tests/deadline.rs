//! Deadline & cancellation acceptance suite (DESIGN.md §14): the
//! end-to-end budget thread from client to queue. Pins the PR's
//! acceptance surface:
//!
//! - **Overload with mixed deadlines**: under a 16384-row overload
//!   where half the traffic carries a short budget, every expired row
//!   is evicted *unexecuted* — proven with the backend-side execute
//!   counters (`per_kernel` rows + `batches`), not just the reply
//!   type — and the extended settlement invariant
//!   `admitted == completed + failed + cancelled` holds.
//! - **Admission shedding**: once a service-rate sample exists, a
//!   budget the backlog has already made hopeless is refused at the
//!   door (typed `DeadlineExceeded`, `shed_at_admission`), never
//!   queued.
//! - **Wire cancellation**: a cancelled remote call frees the server's
//!   slab slot (polled via `OverlayService::live_slots`), and a
//!   drop-storm of abandoned `RemotePending`s leaves zero residual
//!   occupancy — the regression test for the old drop-without-collect
//!   slot leak on the wire path.
//! - **v1 gating**: `deadline_us` suffixes and `Cancel` frames on a
//!   v1-negotiated connection are protocol breaches (typed error,
//!   hangup), never silently misread.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tmfu_overlay::client::OverlayClient;
use tmfu_overlay::dfg::eval;
use tmfu_overlay::exec::{BackendKind, FlatBatch};
use tmfu_overlay::service::{MetricsSnapshot, OverlayService, ServiceError};
use tmfu_overlay::wire::server::WireServer;
use tmfu_overlay::wire::{read_frame, write_frame, Frame, ListenAddr, WireError};

/// The extended settlement invariant every layer must keep.
fn assert_ledger(snap: &MetricsSnapshot, ctx: &str) {
    assert_eq!(
        snap.admitted(),
        snap.completed + snap.failed + snap.cancelled,
        "{ctx}: ledger out of balance: admitted={} completed={} failed={} cancelled={}",
        snap.admitted(),
        snap.completed,
        snap.failed,
        snap.cancelled
    );
}

/// Rows the backends actually executed, from the per-kernel counters
/// (`record_batch` only ever counts rows a worker ran).
fn executed_rows(snap: &MetricsSnapshot) -> u64 {
    snap.per_kernel.iter().map(|(_, n)| n).sum()
}

fn service_with(backend: BackendKind, queue_depth: usize) -> OverlayService {
    // One pipeline with a tiny worker row budget: the queue drains
    // through thousands of dispatch rounds, so a backlog persists long
    // enough for short budgets to lapse deterministically (the same
    // idiom as the fairness suite's contention window).
    OverlayService::builder()
        .backend(backend)
        .pipelines(1)
        .max_batch(4)
        .queue_depth(queue_depth)
        .build()
        .unwrap()
}

fn slow_service(queue_depth: usize) -> OverlayService {
    service_with(BackendKind::Turbo, queue_depth)
}

/// The tentpole acceptance test: 16384 rows of overload on one
/// pipeline, the second half carrying a 100 µs budget that the first
/// half's backlog has already doomed. Every unbudgeted row completes
/// oracle-exact; every budgeted row is shed or expires; the backend
/// execute counters prove the expired rows never ran.
#[test]
fn overloaded_short_deadline_rows_never_reach_a_backend() {
    let service = slow_service(32768);
    let h = service.kernel("gradient").unwrap();
    let dfg = &service.registry().get("gradient").unwrap().dfg;

    const BATCHES: usize = 32;
    const ROWS: usize = 256;
    let mk_batch = |salt: i32| {
        let mut b = FlatBatch::new(5);
        for i in 0..ROWS as i32 {
            b.push(&[3, 5 - salt, 2, 7, i + salt]);
        }
        b
    };

    // Phase 1: 8192 unbudgeted rows — the backlog.
    let mut slow = Vec::new();
    for k in 0..BATCHES {
        let b = mk_batch(k as i32);
        slow.push((h.submit_batch(&b).unwrap(), b));
    }
    // Phase 2: 8192 rows with a 100 µs budget, queued strictly behind
    // phase 1 (same tenant lane + kernel ⇒ FIFO). The backlog needs
    // thousands of dispatch rounds; the budget cannot survive it.
    let budget = Duration::from_micros(100);
    let mut doomed = Vec::new();
    let mut shed_rows = 0u64;
    for k in 0..BATCHES {
        let b = mk_batch(-(k as i32));
        match h.submit_batch_with_deadline(&b, budget) {
            Ok(p) => doomed.push(p),
            // Shed at admission: typed, and never admitted. (Needs a
            // service-rate sample, so early submits may still queue.)
            Err(ServiceError::DeadlineExceeded { .. }) => shed_rows += ROWS as u64,
            Err(other) => panic!("unexpected submit error: {other}"),
        }
    }

    // Every unbudgeted batch completes, oracle-exact.
    for (p, inputs) in slow {
        let out = p.wait().unwrap();
        assert_eq!(out.n_rows(), ROWS);
        for (i, row) in inputs.iter().enumerate() {
            assert_eq!(out.row(i), &eval(dfg, row)[..], "row {i}");
        }
    }
    // Every budgeted batch that was admitted expires typed.
    let mut expired_rows = 0u64;
    for mut p in doomed {
        match p.wait_timeout(Duration::from_secs(60)) {
            Err(ServiceError::DeadlineExceeded { .. }) => expired_rows += ROWS as u64,
            Ok(_) => panic!("a 100us-budget batch outlived an 8192-row backlog"),
            Err(other) => panic!("unexpected wait error: {other}"),
        }
    }
    assert_eq!(shed_rows + expired_rows, (BATCHES * ROWS) as u64);

    let snap = service.metrics();
    assert_ledger(&snap, "overload");
    assert_eq!(snap.completed, (BATCHES * ROWS) as u64);
    assert_eq!(snap.failed, expired_rows);
    assert_eq!(snap.expired_in_queue, expired_rows);
    assert_eq!(snap.shed_at_admission, shed_rows);
    assert_eq!(snap.cancelled, 0);
    // The backend-side proof: exactly the unbudgeted rows executed.
    // Expired and shed rows never produced an execute.
    assert_eq!(executed_rows(&snap), (BATCHES * ROWS) as u64);
    service.shutdown().unwrap();
}

/// Once a service-rate sample exists, an obviously hopeless budget is
/// refused at admission — typed, counted as `shed_at_admission`, and
/// the request is never queued (the queue depth never moves).
#[test]
fn infeasible_budget_is_shed_at_admission() {
    let service = slow_service(65536);
    let h = service.kernel("gradient").unwrap();

    // Prime the per-kernel service-rate EWMA (feasibility is
    // deliberately open until the first sample lands).
    assert_eq!(h.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    // An 8192-row backlog on one pipeline: thousands of rounds deep.
    let mut backlog = FlatBatch::new(5);
    for i in 0..8192i32 {
        backlog.push(&[3, 5, 2, 7, i]);
    }
    let big = h.submit_batch(&backlog).unwrap();

    // 1 µs against that backlog is hopeless under any rate estimate.
    let err = h
        .submit_with_deadline(&[3, 5, 2, 7, 1], Duration::from_micros(1))
        .unwrap_err();
    assert!(
        matches!(err, ServiceError::DeadlineExceeded { ref kernel } if kernel == "gradient"),
        "expected a typed shed, got {err}"
    );

    big.wait().unwrap();
    let snap = service.metrics();
    assert_ledger(&snap, "shed");
    assert!(snap.shed_at_admission >= 1, "shed never counted");
    // Shed requests are never admitted: the ledger only holds the
    // warmup call and the backlog rows.
    assert_eq!(snap.admitted(), 1 + 8192);
    assert_eq!(snap.expired_in_queue, 0);
    service.shutdown().unwrap();
}

fn start_wire(queue_depth: usize) -> (Arc<OverlayService>, WireServer) {
    // The cycle-accurate sim is the slowest backend: its backlogs
    // outlive a client→server cancel round-trip by orders of
    // magnitude, which keeps the occupancy assertions race-free.
    let service = Arc::new(service_with(BackendKind::Sim, queue_depth));
    let server =
        WireServer::bind(Arc::clone(&service), &ListenAddr::parse("127.0.0.1:0")).unwrap();
    (service, server)
}

/// Poll a slab/inflight gauge until it reaches `want` (cancellation is
/// asynchronous on the wire: the frame travels, the reactor settles).
fn await_gauge(what: &str, want: usize, read: impl Fn() -> usize) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let got = read();
        if got == want {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{what} stuck at {got}, wanted {want}"
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// An explicitly cancelled remote call releases the server's slab slot
/// and purges its queued row — observed from the server side, not
/// inferred from the client.
#[test]
fn remote_cancel_frees_the_server_slab_slot() {
    let (service, server) = start_wire(32768);
    let client = OverlayClient::connect(&server.addr().to_string()).unwrap();
    let gradient = client.kernel("gradient").unwrap();

    // Pin the single worker down with a 16384-row batch (slot 1): at
    // 4 rows per dispatch round that is 4096 lock round-trips of
    // cycle-accurate simulation — far longer than the cancel exchange.
    let mut backlog = FlatBatch::new(5);
    for i in 0..16384i32 {
        backlog.push(&[3, 5, 2, 7, i]);
    }
    let big = gradient.submit_batch(&backlog).unwrap();

    // Eight queued singles behind it: occupancy climbs to 9.
    let mut victims = Vec::new();
    for i in 0..8i32 {
        victims.push(gradient.submit(&[0, 0, 0, 0, i]).unwrap());
    }
    await_gauge("live_slots", 9, || service.live_slots());

    // Cancel them all; the server must return to the big batch alone.
    for p in &mut victims {
        p.cancel();
    }
    await_gauge("live_slots after cancel", 1, || service.live_slots());

    let out = big.wait().unwrap();
    assert_eq!(out.n_rows(), 16384);
    await_gauge("inflight", 0, || server.ctl().inflight());
    let snap = service.metrics();
    assert_ledger(&snap, "remote cancel");
    // The worker never got near the queued singles (it was thousands
    // of rounds deep in the backlog), so all eight count as cancelled.
    assert_eq!(snap.cancelled, 8);
    assert_eq!(executed_rows(&snap), 16384);

    drop(victims);
    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

/// Regression: dropping a `RemotePending` without collecting it used
/// to strand the server-side slot until the connection died. Now the
/// drop sends `Cancel`; a storm of 64 drops leaves zero residual slab
/// occupancy while the connection stays alive and usable.
#[test]
fn drop_storm_leaves_no_residual_occupancy() {
    let (service, server) = start_wire(16384);
    let client = OverlayClient::connect(&server.addr().to_string()).unwrap();
    let gradient = client.kernel("gradient").unwrap();

    let mut backlog = FlatBatch::new(5);
    for i in 0..2048i32 {
        backlog.push(&[3, 5, 2, 7, i]);
    }
    let big = gradient.submit_batch(&backlog).unwrap();

    for i in 0..64i32 {
        let p = gradient.submit(&[1, 1, 1, 1, i]).unwrap();
        drop(p); // fire-and-forget abandon: must not leak the slot
    }
    await_gauge("live_slots after drop storm", 1, || service.live_slots());

    // The connection survived the storm and still serves.
    assert_eq!(big.wait().unwrap().n_rows(), 2048);
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);
    await_gauge("live_slots drained", 0, || service.live_slots());
    assert_ledger(&service.metrics(), "drop storm");

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

/// The client's deadline budget rides the Call frame: behind a
/// backlog it expires (or sheds) server-side, arrives as the typed
/// error, and `call_with_deadline`'s cancel-on-timeout reclaims the
/// slot — the deadline miss leaves nothing behind on the server.
#[test]
fn deadline_budget_rides_the_wire_and_misses_clean() {
    let (service, server) = start_wire(16384);
    let client = OverlayClient::connect(&server.addr().to_string()).unwrap();
    let gradient = client.kernel("gradient").unwrap();

    let mut backlog = FlatBatch::new(5);
    for i in 0..8192i32 {
        backlog.push(&[3, 5, 2, 7, i]);
    }
    let big = gradient.submit_batch(&backlog).unwrap();
    await_gauge("live_slots", 1, || service.live_slots());

    let err = gradient
        .call_with_deadline(&[3, 5, 2, 7, 1], Duration::from_millis(2))
        .unwrap_err();
    assert!(
        matches!(err, ServiceError::DeadlineExceeded { ref kernel } if kernel == "gradient"),
        "expected DeadlineExceeded over the wire, got {err}"
    );
    // Whichever path lost the race (queue expiry, admission shed, or
    // local timeout + Cancel), the slot must be reclaimed.
    await_gauge("live_slots after miss", 1, || service.live_slots());

    assert_eq!(big.wait().unwrap().n_rows(), 8192);
    let snap = service.metrics();
    assert_ledger(&snap, "wire deadline");
    assert!(
        snap.expired_in_queue + snap.shed_at_admission + snap.cancelled >= 1,
        "the missed deadline must be visible in a cause counter"
    );
    // An unbudgeted call on the same session still works afterwards.
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

/// v1 gating, straight off a raw socket: a `deadline_us` suffix or a
/// `Cancel` frame on a v1-negotiated connection is a typed protocol
/// breach followed by hangup — never silently misread.
#[test]
fn v1_connections_refuse_deadlines_and_cancel() {
    let (service, server) = start_wire(64);
    let ListenAddr::Tcp(addr) = server.addr().clone() else {
        panic!("expected tcp")
    };
    let gradient_id = service.kernel("gradient").unwrap().id().0;

    // Case 1: Call + deadline_us on v1.
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &Frame::Hello { id: 0, min: 1, max: 1, token: None }).unwrap();
        assert!(matches!(
            read_frame(&mut s).unwrap().unwrap(),
            Frame::HelloOk { version: 1, .. }
        ));
        write_frame(
            &mut s,
            &Frame::Call {
                id: 1,
                kernel: gradient_id,
                inputs: vec![3, 5, 2, 7, 1],
                deadline_us: Some(5_000),
            },
        )
        .unwrap();
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::Error { id, err: WireError::Malformed { message } } => {
                assert_eq!(id, 1);
                assert!(message.contains("deadline_us requires protocol v2"), "{message}");
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        // Breach ⇒ hangup.
        assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
    }

    // Case 2: Cancel on v1.
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &Frame::Hello { id: 0, min: 1, max: 1, token: None }).unwrap();
        assert!(matches!(
            read_frame(&mut s).unwrap().unwrap(),
            Frame::HelloOk { version: 1, .. }
        ));
        write_frame(&mut s, &Frame::Cancel { id: 7 }).unwrap();
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::Error { id, err: WireError::Malformed { message } } => {
                assert_eq!(id, 7);
                assert!(message.contains("Cancel requires protocol v2"), "{message}");
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
        assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
    }

    // The server survives both breaches and still serves v2 clients.
    let client = OverlayClient::connect(&server.addr().to_string()).unwrap();
    assert_eq!(client.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}
