//! Wire-protocol integration tests: a real client and a real server
//! in one process, talking through OS sockets (TCP with ephemeral
//! ports; one test covers the Unix transport). Covers the acceptance
//! surface of the wire PR: resolve/call/call_batch/submit round trips,
//! every server-originating `ServiceError` variant arriving typed over
//! the socket, version negotiation, malformed frames, and mid-call
//! disconnects leaving the server healthy.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tmfu_overlay::client::{ClientBuilder, OverlayClient};
use tmfu_overlay::dfg::eval;
use tmfu_overlay::exec::{BackendKind, FlatBatch};
use tmfu_overlay::service::{OverlayService, ServiceError};
use tmfu_overlay::util::bench::os_thread_count;
use tmfu_overlay::util::prng::Rng;
use tmfu_overlay::wire::server::WireServer;
use tmfu_overlay::wire::{read_frame, write_frame, Frame, ListenAddr, WireError};

fn start(backend: BackendKind, queue_depth: usize) -> (Arc<OverlayService>, WireServer) {
    let service = Arc::new(
        OverlayService::builder()
            .backend(backend)
            .pipelines(2)
            .max_batch(8)
            .queue_depth(queue_depth)
            .build()
            .unwrap(),
    );
    let server = WireServer::bind(Arc::clone(&service), &ListenAddr::parse("127.0.0.1:0"))
        .unwrap();
    (service, server)
}

fn connect(server: &WireServer) -> OverlayClient {
    OverlayClient::connect(&server.addr().to_string()).unwrap()
}

#[test]
fn resolve_call_batch_submit_and_metrics_round_trip() {
    let (service, server) = start(BackendKind::Turbo, 1024);
    let client = connect(&server);
    assert_eq!(client.version(), 2);
    assert_eq!(client.backend(), "turbo");

    // Resolve mirrors OverlayService::kernel: id + arities, once.
    let gradient = client.kernel("gradient").unwrap();
    assert_eq!(gradient.name(), "gradient");
    assert_eq!(gradient.arity(), 5);
    assert_eq!(gradient.n_outputs(), 1);
    assert_eq!(
        gradient.id(),
        service.kernel("gradient").unwrap().id().0,
        "remote id must be the service's dense id"
    );

    // Blocking call.
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    // Batch: rows travel flat and come back in row order, oracle-exact.
    let compiled = service.registry().get("poly6").unwrap().clone();
    let poly6 = client.kernel("poly6").unwrap();
    let mut rng = Rng::new(41);
    let mut batch = FlatBatch::new(poly6.arity());
    for _ in 0..23 {
        batch.push_iter((0..poly6.arity()).map(|_| rng.range_i64(-2000, 2000) as i32));
    }
    let out = poly6.call_batch(&batch).unwrap();
    assert_eq!(out.n_rows(), 23);
    assert_eq!(out.arity(), poly6.n_outputs());
    for (i, row) in batch.iter().enumerate() {
        assert_eq!(out.row(i), &eval(&compiled.dfg, row)[..], "row {i}");
    }

    // Many in-flight submits on one socket; replies correlate by id
    // even when collected out of submission order.
    let grad_dfg = &service.registry().get("gradient").unwrap().dfg;
    let mut jobs = Vec::new();
    for i in 0..16 {
        let inputs = vec![i, 5 - i, 2, 7, -i];
        let want = eval(grad_dfg, &inputs);
        jobs.push((gradient.submit(&inputs).unwrap(), want));
    }
    for (p, want) in jobs.into_iter().rev() {
        assert_eq!(p.wait().unwrap(), want);
    }

    // Poll + deadline variants of the pending mirror.
    let mut p = gradient.submit(&[3, 5, 2, 7, 1]).unwrap();
    let got = loop {
        if let Some(r) = p.poll() {
            break r.unwrap();
        }
        std::thread::yield_now();
    };
    assert_eq!(got, vec![36]);
    let mut p = gradient.submit(&[3, 5, 2, 7, 1]).unwrap();
    assert_eq!(
        p.wait_deadline(Instant::now() + Duration::from_secs(10)).unwrap(),
        vec![36]
    );

    // Metrics over the wire: same JSON field names as --metrics-json.
    let m = client.metrics().unwrap();
    assert_eq!(m.get("backend").as_str(), Some("turbo"));
    let completed = m.get("completed").as_i64().unwrap();
    assert_eq!(completed as u64, service.completed());
    assert!(completed >= 1 + 23 + 16 + 2, "{completed}");
    assert_eq!(m.get("rejected").as_i64(), Some(0));
    assert!(m.get("per_kernel").get("gradient").as_i64().unwrap() >= 18);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn service_errors_round_trip_typed_over_the_socket() {
    let (service, server) = start(BackendKind::Ref, 2);
    let client = connect(&server);

    // UnknownKernel from resolve.
    assert_eq!(
        client.kernel("nonesuch").unwrap_err(),
        ServiceError::UnknownKernel("nonesuch".to_string())
    );

    let gradient = client.kernel("gradient").unwrap();

    // ShapeMismatch: the client does not pre-validate, so the server's
    // typed reply is what we observe.
    assert_eq!(
        gradient.call(&[1, 2]).unwrap_err(),
        ServiceError::ShapeMismatch {
            kernel: "gradient".to_string(),
            expected: 5,
            got: 2
        }
    );

    // EmptyBatch: a zero-row batch crosses the wire and is refused by
    // the service, not the codec.
    assert_eq!(
        gradient.call_batch(&FlatBatch::new(5)).unwrap_err(),
        ServiceError::EmptyBatch {
            kernel: "gradient".to_string()
        }
    );

    // Rejected: a batch wider than the queue depth is deterministically
    // refused by admission control, with the kernel named.
    let rows: Vec<Vec<i32>> = (0..3).map(|i| vec![i; 5]).collect();
    match gradient.call_batch(&FlatBatch::from_rows(5, &rows)).unwrap_err() {
        ServiceError::Rejected { kernel, limit, .. } => {
            assert_eq!(kernel, "gradient");
            assert_eq!(limit, 2);
        }
        other => panic!("expected Rejected, got {other}"),
    }
    assert_eq!(service.metrics().rejected, 3);

    // ShutDown: the service drains behind the still-running server;
    // the session then answers the typed shutdown error — over TCP.
    service.shutdown().unwrap();
    assert_eq!(gradient.call(&[0; 5]).unwrap_err(), ServiceError::ShutDown);
    assert_eq!(
        gradient.submit(&[0; 5]).unwrap().wait().unwrap_err(),
        ServiceError::ShutDown
    );
    // Metrics still served after shutdown.
    assert!(client.metrics().unwrap().get("completed").as_i64().is_some());

    drop(client);
    server.shutdown();
}

#[test]
fn version_mismatch_is_refused_with_the_server_range() {
    let (service, server) = start(BackendKind::Turbo, 64);
    let ListenAddr::Tcp(addr) = server.addr().clone() else {
        panic!("expected tcp")
    };
    // Handcrafted handshake from a client that only speaks v9.
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    write_frame(&mut s, &Frame::Hello { id: 7, min: 9, max: 9, token: None }).unwrap();
    match read_frame(&mut s).unwrap().unwrap() {
        Frame::Error { id, err } => {
            assert_eq!(id, 7);
            assert_eq!(err, WireError::VersionMismatch { min: 1, max: 2 });
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    // The server hangs up after refusing.
    assert!(read_frame(&mut s).unwrap().is_none());

    // A well-versioned client still connects fine afterwards.
    let client = connect(&server);
    assert_eq!(client.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn malformed_frames_get_typed_errors_and_a_hangup() {
    let (service, server) = start(BackendKind::Turbo, 64);
    let ListenAddr::Tcp(addr) = server.addr().clone() else {
        panic!("expected tcp")
    };

    // A hostile length prefix: refused before allocation, connection
    // closed, acceptor unharmed. (Exactly 4 bytes, so the server has
    // no unread input left when it hangs up — a clean FIN, not RST.)
    {
        use std::io::Write as _;
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        s.write_all(&[0xff, 0xff, 0xff, 0xff]).unwrap();
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::Error {
                err: WireError::Malformed { message },
                ..
            } => assert!(message.contains("exceeds max"), "{message}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
        assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
    }

    // A non-Hello first frame breaks the handshake contract.
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &Frame::GetMetrics { id: 3 }).unwrap();
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::Error {
                id,
                err: WireError::Malformed { message },
            } => {
                assert_eq!(id, 3);
                assert!(message.contains("Hello"), "{message}");
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    // A server-side opcode after a valid handshake is a breach too.
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &Frame::Hello { id: 0, min: 1, max: 1, token: None }).unwrap();
        assert!(matches!(
            read_frame(&mut s).unwrap().unwrap(),
            Frame::HelloOk { .. }
        ));
        write_frame(
            &mut s,
            &Frame::Reply {
                id: 5,
                batch: FlatBatch::new(1),
            },
        )
        .unwrap();
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::Error {
                err: WireError::Malformed { message },
                ..
            } => assert!(message.contains("Reply"), "{message}"),
            other => panic!("expected Malformed, got {other:?}"),
        }
        assert!(read_frame(&mut s).unwrap().is_none());
    }

    // After all that abuse, a real client still gets served.
    let client = connect(&server);
    assert_eq!(client.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn mid_call_disconnect_leaves_the_server_healthy() {
    let (service, server) = start(BackendKind::Sim, 1024);

    // Raw socket: submit a call, then vanish without reading the
    // reply. The server's reply write fails silently; nothing else
    // notices.
    let ListenAddr::Tcp(addr) = server.addr().clone() else {
        panic!("expected tcp")
    };
    {
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &Frame::Hello { id: 0, min: 1, max: 1, token: None }).unwrap();
        assert!(matches!(
            read_frame(&mut s).unwrap().unwrap(),
            Frame::HelloOk { .. }
        ));
        let gradient_id = service.kernel("gradient").unwrap().id().0;
        write_frame(
            &mut s,
            &Frame::Call {
                id: 1,
                kernel: gradient_id,
                inputs: vec![3, 5, 2, 7, 1],
                deadline_us: None,
            },
        )
        .unwrap();
        // Drop the stream with the reply still in flight.
    }

    // Library client: outstanding pendings resolve (with the reply if
    // it won the race, else Disconnected) when the client is dropped.
    let client = connect(&server);
    let gradient = client.kernel("gradient").unwrap();
    let pending = gradient.submit(&[3, 5, 2, 7, 1]).unwrap();
    drop(client);
    match pending.wait() {
        Ok(row) => assert_eq!(row, vec![36]),
        Err(ServiceError::Disconnected { .. }) => {}
        Err(other) => panic!("unexpected error after disconnect: {other}"),
    }
    // The session itself now reports the dead connection.
    assert!(matches!(
        gradient.call(&[3, 5, 2, 7, 1]),
        Err(ServiceError::Disconnected { .. }) | Err(ServiceError::Backend { .. })
    ));

    // A fresh connection is served as if nothing happened.
    let client = connect(&server);
    assert_eq!(client.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn unix_socket_transport_serves_and_cleans_up() {
    let path = std::env::temp_dir().join(format!("tmfu-wire-test-{}.sock", std::process::id()));
    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(1)
            .build()
            .unwrap(),
    );
    let addr = ListenAddr::Unix(path.clone());
    let server = WireServer::bind(Arc::clone(&service), &addr).unwrap();
    assert!(path.exists(), "socket file must exist while bound");

    let client = OverlayClient::connect(&format!("unix:{}", path.display())).unwrap();
    let chebyshev = client.kernel("chebyshev").unwrap();
    let compiled = service.registry().get("chebyshev").unwrap().clone();
    for x in [-3, 0, 5, 111] {
        assert_eq!(chebyshev.call(&[x]).unwrap(), eval(&compiled.dfg, &[x]));
    }

    drop(client);
    server.shutdown();
    assert!(!path.exists(), "socket file must be removed on shutdown");
    service.shutdown().unwrap();
}

/// The completion-slab reactor property: a connection serves any
/// number of in-flight calls with its two fixed threads. The previous
/// design spawned a waiter thread per in-flight call and only reaped
/// the finished ones when the *next* frame arrived, so an
/// idle-after-burst connection pinned completed threads' stacks
/// indefinitely — this test pins down both halves of the fix.
#[test]
fn in_flight_burst_spawns_no_per_call_threads() {
    if os_thread_count().is_none() {
        eprintln!("skipping: /proc/self/status not available");
        return;
    }
    let (service, server) = start(BackendKind::Turbo, 4096);
    let client = connect(&server);
    let gradient = client.kernel("gradient").unwrap();
    // Steady state first: connection threads exist, one call served.
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);
    let before = os_thread_count().unwrap();

    // Burst: hundreds of concurrent submits on the one connection.
    let mut replies = Vec::new();
    for i in 0..512i32 {
        replies.push(gradient.submit(&[i, 5, 2, 7, -i]).unwrap());
    }
    let during = os_thread_count().unwrap();
    for p in replies {
        p.wait().unwrap();
    }
    // Other tests in this binary run concurrently and spawn their own
    // servers, so allow generous slack — the per-call design this
    // guards against would add *hundreds* here, not a handful.
    assert!(
        during <= before + 64,
        "thread count grew with in-flight calls: {during} during the burst vs {before} before"
    );

    // Idle after the burst: nothing stays pinned waiting for a next
    // frame to trigger reaping.
    std::thread::sleep(Duration::from_millis(100));
    let after = os_thread_count().unwrap();
    assert!(
        after <= before + 64,
        "idle-after-burst connection holds extra threads: {after} vs {before} before"
    );
    // And the connection still serves.
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

/// Partial frames are a legal wire state, not an error: a peer may
/// dribble a frame one byte at a time and the server must reassemble
/// it exactly (the patient reader's frame-boundary bookkeeping).
#[test]
fn byte_at_a_time_frames_are_served_intact() {
    let (service, server) = start(BackendKind::Turbo, 64);
    let ListenAddr::Tcp(addr) = server.addr().clone() else {
        panic!("expected tcp")
    };
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    s.set_nodelay(true).unwrap();
    // Serialize the whole conversation locally, then dribble it.
    let gradient_id = service.kernel("gradient").unwrap().id().0;
    let mut buf = Vec::new();
    write_frame(&mut buf, &Frame::Hello { id: 0, min: 1, max: 2, token: None }).unwrap();
    write_frame(
        &mut buf,
        &Frame::Call {
            id: 1,
            kernel: gradient_id,
            inputs: vec![3, 5, 2, 7, 1],
            deadline_us: None,
        },
    )
    .unwrap();
    use std::io::Write as _;
    for b in buf {
        s.write_all(&[b]).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_micros(300));
    }
    assert!(matches!(
        read_frame(&mut s).unwrap().unwrap(),
        Frame::HelloOk { .. }
    ));
    match read_frame(&mut s).unwrap().unwrap() {
        Frame::Reply { id, batch } => {
            assert_eq!(id, 1);
            assert_eq!(batch.row(0), &[36]);
        }
        other => panic!("expected Reply, got {other:?}"),
    }
    assert_eq!(server.ctl().inflight(), 0);

    drop(s);
    server.shutdown();
    service.shutdown().unwrap();
}

/// A peer that stalls *mid-frame* past the read deadline can never
/// re-align the stream; the server must drop it — promptly, with
/// nothing leaked — rather than wedge the connection thread forever.
#[test]
fn mid_frame_stall_past_the_read_deadline_is_dropped_not_wedged() {
    let (service, server) = start(BackendKind::Turbo, 64);
    server.ctl().set_read_deadline(Duration::from_millis(150));
    let ListenAddr::Tcp(addr) = server.addr().clone() else {
        panic!("expected tcp")
    };
    let mut s = std::net::TcpStream::connect(&addr).unwrap();
    write_frame(&mut s, &Frame::Hello { id: 0, min: 1, max: 2, token: None }).unwrap();
    assert!(matches!(
        read_frame(&mut s).unwrap().unwrap(),
        Frame::HelloOk { .. }
    ));
    // A length prefix promising 10 bytes, one byte of body, then
    // silence.
    use std::io::Write as _;
    s.write_all(&[10, 0, 0, 0, 0x05]).unwrap();
    s.flush().unwrap();
    // The server tears both halves down once the deadline passes; our
    // read unblocks with EOF or a reset long before the guard timeout.
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    use std::io::Read as _;
    let t0 = Instant::now();
    let mut buf = [0u8; 16];
    match s.read(&mut buf) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("expected a hangup, got {n} bytes"),
    }
    assert!(t0.elapsed() < Duration::from_secs(5), "took {:?}", t0.elapsed());
    // Nothing was admitted, nothing leaked.
    assert_eq!(server.ctl().inflight(), 0);

    // The server still serves fresh connections afterwards.
    let client = connect(&server);
    assert_eq!(client.kernel("gradient").unwrap().call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

/// Graceful drain end to end: `Health` reports serving, a `Drain`
/// frame (even one followed by trailing garbage) is acknowledged and
/// stops the server, in-flight calls still complete, `wait()` returns,
/// and the ledger is balanced.
#[test]
fn drain_finishes_in_flight_work_and_survives_trailing_garbage() {
    let (service, server) = start(BackendKind::Turbo, 1024);
    let ctl = server.ctl();
    let client = connect(&server);
    let gradient = client.kernel("gradient").unwrap();
    let health = client.health().unwrap();
    assert!(!health.draining);

    // A call in flight while the drain lands.
    let pending = gradient.submit(&[3, 5, 2, 7, 1]).unwrap();
    {
        let ListenAddr::Tcp(addr) = server.addr().clone() else {
            panic!("expected tcp")
        };
        let mut s = std::net::TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &Frame::Hello { id: 0, min: 1, max: 2, token: None }).unwrap();
        assert!(matches!(
            read_frame(&mut s).unwrap().unwrap(),
            Frame::HelloOk { .. }
        ));
        write_frame(&mut s, &Frame::Drain { id: 9 }).unwrap();
        // Bytes after the drain must never wedge the server: it has
        // stopped reading this connection.
        use std::io::Write as _;
        let _ = s.write_all(b"trailing garbage after the drain");
        match read_frame(&mut s).unwrap().unwrap() {
            Frame::HealthOk { id, status, .. } => {
                assert_eq!(id, 9);
                assert_eq!(status, 1, "ack must report draining");
            }
            other => panic!("expected HealthOk, got {other:?}"),
        }
        // Hangup, not a wedge.
        assert!(matches!(read_frame(&mut s), Ok(None) | Err(_)));
    }
    // The in-flight reply still arrives (drain finishes work, it does
    // not drop it) ...
    assert_eq!(pending.wait().unwrap(), vec![36]);
    // ... and the drained acceptor lets wait() return instead of
    // serving forever.
    server.wait();
    assert_eq!(ctl.inflight(), 0, "admitted == completed + failed");

    drop(client);
    service.shutdown().unwrap();
}

/// Satellite regression for the client timeouts: a server that
/// completes the handshake and then goes silent (never replies, never
/// closes) must yield a typed `Disconnected` within the configured
/// read-timeout window — not a 30 s (or forever) hang.
#[test]
fn silent_socket_yields_typed_disconnected_not_a_hang() {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let hello = read_frame(&mut s).unwrap().unwrap();
        write_frame(
            &mut s,
            &Frame::HelloOk {
                id: hello.request_id(),
                version: 2,
                backend: "fake".to_string(),
            },
        )
        .unwrap();
        // Return the socket so it stays open (silent) until joined.
        s
    });
    let client = ClientBuilder::new()
        .read_timeout(Some(Duration::from_millis(120)))
        .connect(&addr)
        .unwrap();
    let t0 = Instant::now();
    let err = client.kernel("gradient").unwrap_err();
    assert!(
        matches!(err, ServiceError::Disconnected { .. }),
        "expected Disconnected, got {err}"
    );
    // Two idle strikes at 120 ms each plus slack — nowhere near 30 s.
    assert!(t0.elapsed() < Duration::from_secs(5), "took {:?}", t0.elapsed());
    let _ = fake.join();
}

#[test]
fn concurrent_sessions_share_one_connection() {
    let (service, server) = start(BackendKind::Turbo, 1024);
    let client = connect(&server);
    let gradient = client.kernel("gradient").unwrap();
    let dfg = service.registry().get("gradient").unwrap().dfg.clone();
    let mut threads = Vec::new();
    for t in 0..4i32 {
        let session = gradient.clone();
        let dfg = dfg.clone();
        threads.push(std::thread::spawn(move || {
            for i in 0..10 {
                let inputs = vec![t, i, t + i, 7, -i];
                assert_eq!(session.call(&inputs).unwrap(), eval(&dfg, &inputs));
            }
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(service.completed(), 40);

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}
