//! Integration gates for the static verifier (DESIGN.md §12).
//!
//! The contract under test is **zero false negatives**: every corrupted
//! compiled-kernel form that the runtime differential oracle (mutant
//! tape vs. the DFG interpreter; ref vs. turbo on mutant artifacts)
//! shows misbehaving must be rejected statically, before it could ever
//! be loaded. The mutation corpus comes from `verify::mutate`; the
//! oracle runs every mutant here and cross-checks the verdicts.
//!
//! Also covered: the committed `benchmarks/dfg` artifacts verify clean,
//! every Table II kernel verifies clean and serves correctly on every
//! toolchain-free backend, and `OverlayService::builder()` refuses a
//! corrupted artifact with the typed `ServiceError::InvalidKernel`.

use std::panic::{catch_unwind, AssertUnwindSafe};
use tmfu_overlay::bench_suite;
use tmfu_overlay::dfg::{dfg_from_json, eval};
use tmfu_overlay::exec::{BackendKind, CompiledKernel, FlatBatch, Tape, TapeArena};
use tmfu_overlay::sched::{program_to_json, Program};
use tmfu_overlay::service::{OverlayService, ServiceError};
use tmfu_overlay::util::prng::Rng;
use tmfu_overlay::verify::{self, mutate};

/// Random input packets for one kernel.
fn cases(arity: usize, rng: &mut Rng, n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|_| (0..arity).map(|_| rng.next_i32() % 1000).collect())
        .collect()
}

/// The runtime differential oracle: execute `tape` on `inputs` and
/// diff against the DFG interpreter. Returns `true` when the tape
/// *misbehaves* — panics (slot out of range trips a slice bounds
/// check; the tape interpreter is entirely safe code, so corruption
/// panics instead of invoking UB) or produces any diverging packet.
fn misbehaves(k: &CompiledKernel, tape: &Tape, inputs: &[Vec<i32>]) -> bool {
    let run = catch_unwind(AssertUnwindSafe(|| {
        let batch = FlatBatch::from_rows(k.n_inputs, inputs);
        let mut arena = TapeArena::new();
        let mut out = FlatBatch::new(tape.n_outputs());
        tape.execute_into(&batch, &mut arena, &mut out);
        out.to_rows()
    }));
    match run {
        Err(_) => true, // panicked: corrupt by demonstration
        Ok(rows) => {
            rows.len() != inputs.len()
                || inputs
                    .iter()
                    .zip(&rows)
                    .any(|(packet, got)| *got != eval(&k.dfg, packet))
        }
    }
}

/// Zero false negatives over the tape-mutation corpus: every mutant
/// the oracle shows misbehaving is rejected by `check_tape_against`.
/// (The verifier is in fact stricter — every mutant differs from a
/// fresh lowering in at least one field — but the gate asserted here
/// is exactly the safety contract.)
#[test]
fn every_misbehaving_tape_mutant_is_rejected_statically() {
    let mut rng = Rng::new(0x5EED_CAFE);
    let mut misbehaving = 0usize;
    let mut total = 0usize;
    for name in bench_suite::all_names() {
        let k = CompiledKernel::compile(bench_suite::load(name).unwrap()).unwrap();
        let inputs = cases(k.n_inputs, &mut rng, 24);
        // Sanity: the pristine tape behaves and verifies.
        assert!(!misbehaves(&k, &k.tape, &inputs), "{name}: pristine tape diverged");
        verify::check_tape_against(&k.name, &k.dfg, &k.program, &k.tape).unwrap();
        for m in mutate::tape_mutants(&k, &mut rng, 3 * mutate::TAPE_MUTATION_KINDS) {
            total += 1;
            let rejected =
                verify::check_tape_against(&k.name, &k.dfg, &k.program, &m.tape).is_err();
            if misbehaves(&k, &m.tape, &inputs) {
                misbehaving += 1;
                assert!(
                    rejected,
                    "FALSE NEGATIVE: oracle shows mutant misbehaving but the \
                     verifier passed it — {}",
                    m.desc
                );
            }
        }
    }
    // The corpus must actually exercise the contract.
    assert!(total >= 100, "mutation corpus too small ({total})");
    assert!(
        misbehaving * 2 >= total,
        "oracle found too few misbehaving mutants ({misbehaving}/{total})"
    );
}

/// Artifact-level mutation gate: structural corruption of the
/// committed interchange form must be rejected; mutants the verifier
/// accepts (semantically-consistent rewrites) must be genuinely
/// harmless — the ref and turbo backends still agree on the kernel the
/// rewritten document describes.
#[test]
fn artifact_mutants_rejected_or_provably_harmless() {
    let mut rng = Rng::new(0xA11FAC75);
    for name in bench_suite::all_names() {
        let g = bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        let doc = program_to_json(&g, &p);
        verify::verify_artifact_json(name, &doc)
            .unwrap_or_else(|e| panic!("pristine artifact rejected: {e}"));
        for m in mutate::artifact_mutants(&doc, &mut rng, 2 * mutate::ARTIFACT_MUTATION_KINDS) {
            let verdict = verify::verify_artifact_json(name, &m.doc);
            if m.must_reject {
                assert!(
                    verdict.is_err(),
                    "{name}: structural mutant passed verification: {}",
                    m.desc
                );
                continue;
            }
            if verdict.is_ok() {
                // Accepted rewrite: prove it harmless with the
                // differential oracle on the kernel it now describes.
                let g2 = dfg_from_json(m.doc.get("dfg")).unwrap();
                let k2 = CompiledKernel::compile(g2).unwrap();
                let inputs = cases(k2.n_inputs, &mut rng, 8);
                assert!(
                    !misbehaves(&k2, &k2.tape, &inputs),
                    "{name}: accepted mutant misbehaves at runtime: {}",
                    m.desc
                );
            }
        }
    }
}

/// The committed `benchmarks/dfg` interchange files all verify clean
/// (the same gate `tmfu verify` and `make verify` enforce).
#[test]
fn committed_artifacts_verify_clean() {
    // Cargo runs integration tests with cwd = the package root (rust/).
    let dir = std::path::Path::new("../benchmarks/dfg");
    let names = verify::verify_artifacts_dir(dir).unwrap();
    assert_eq!(
        names.len(),
        bench_suite::all_names().len(),
        "artifact set out of sync with the bench suite"
    );
}

/// Every Table II kernel verifies clean and serves oracle-correct
/// results on every toolchain-free backend (the builder now runs the
/// verifier, so `build()` succeeding *is* the verification pass).
#[test]
fn every_kernel_verifies_and_serves_on_all_backends() {
    let mut rng = Rng::new(0xB0A7);
    for kind in [BackendKind::Ref, BackendKind::Turbo, BackendKind::Sim] {
        let service = OverlayService::builder()
            .backend(kind)
            .pipelines(1)
            .max_batch(8)
            .build()
            .unwrap();
        for h in service.handles() {
            let packet: Vec<i32> = (0..h.arity()).map(|_| rng.next_i32() % 100).collect();
            let got = h.call(&packet).unwrap();
            let want = eval(&h.compiled().dfg, &packet);
            assert_eq!(got, want, "{} on {:?}", h.name(), kind);
        }
        service.shutdown().unwrap();
    }
}

/// `OverlayService::builder()` refuses a corrupted artifact directory
/// with the typed `InvalidKernel` error — the broken kernel is never
/// loaded — and accepts the pristine equivalent.
#[test]
fn builder_rejects_corrupted_artifact_with_typed_error() {
    let dir = std::env::temp_dir().join(format!("tmfu-verify-gate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Pristine artifacts for two kernels.
    for name in ["gradient", "poly6"] {
        let g = bench_suite::load(name).unwrap();
        let p = Program::schedule(&g).unwrap();
        std::fs::write(
            dir.join(format!("{name}.json")),
            program_to_json(&g, &p).to_string_pretty(),
        )
        .unwrap();
    }
    let service = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .pipelines(1)
        .kernels_from_artifacts(&dir)
        .build()
        .unwrap();
    let g = bench_suite::load("gradient").unwrap();
    let packet = vec![3, -1, 4, 1, -5];
    assert_eq!(
        service.kernel("gradient").unwrap().call(&packet).unwrap(),
        eval(&g, &packet)
    );
    service.shutdown().unwrap();

    // Corrupt one: structural schedule damage (ii bump — kind 0 is
    // always applicable and always must_reject).
    let p = Program::schedule(&g).unwrap();
    let doc = program_to_json(&g, &p);
    let mut rng = Rng::new(1);
    let m = mutate::artifact_mutant(&doc, 0, &mut rng).unwrap();
    assert!(m.must_reject);
    std::fs::write(dir.join("gradient.json"), m.doc.to_string_pretty()).unwrap();

    let err = OverlayService::builder()
        .backend(BackendKind::Turbo)
        .pipelines(1)
        .kernels_from_artifacts(&dir)
        .build()
        .unwrap_err();
    match err {
        ServiceError::InvalidKernel { ref kernel, ref detail } => {
            assert_eq!(kernel, "gradient");
            assert!(detail.contains("verify"), "detail lacks provenance: {detail}");
        }
        other => panic!("expected InvalidKernel, got {other:?}"),
    }

    std::fs::remove_dir_all(&dir).ok();
}
