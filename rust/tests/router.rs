//! Router integration tests: real backends, a real router, and a real
//! client in one process, talking through OS sockets. The centerpiece
//! is the chaos gate — one of two replicas "kill -9"ed mid-burst (its
//! sockets vanish with replies owed, via the scripted fault plan) and
//! every call must still settle bit-exactly on the survivor, with the
//! router's ledger balancing to `admitted == completed + failed`.

use std::sync::Arc;
use std::time::{Duration, Instant};
use tmfu_overlay::client::OverlayClient;
use tmfu_overlay::dfg::eval;
use tmfu_overlay::exec::{BackendKind, FlatBatch};
use tmfu_overlay::router::{Router, RouterConfig};
use tmfu_overlay::service::{OverlayService, ServiceError};
use tmfu_overlay::wire::fault::FaultPlan;
use tmfu_overlay::wire::server::WireServer;
use tmfu_overlay::wire::ListenAddr;

fn backend(pipelines: usize) -> (Arc<OverlayService>, WireServer) {
    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Turbo)
            .pipelines(pipelines)
            .max_batch(8)
            .queue_depth(4096)
            .build()
            .unwrap(),
    );
    let server = WireServer::bind(Arc::clone(&service), &ListenAddr::parse("127.0.0.1:0"))
        .unwrap();
    (service, server)
}

/// Test tuning: fast probes and short backoffs so replica death is
/// detected and retried within milliseconds, not seconds.
fn quick_cfg(backends: Vec<String>) -> RouterConfig {
    let mut cfg = RouterConfig::new(backends);
    cfg.probe_interval = Duration::from_millis(100);
    cfg.call_deadline = Duration::from_secs(15);
    cfg.max_retries = 5;
    cfg.backoff_base = Duration::from_millis(20);
    cfg.backoff_cap = Duration::from_millis(200);
    cfg.connect_timeout = Duration::from_secs(2);
    cfg.read_timeout = Duration::from_secs(5);
    cfg
}

fn start_router(backends: Vec<String>) -> Router {
    Router::start(quick_cfg(backends), &ListenAddr::parse("127.0.0.1:0")).unwrap()
}

/// Like [`start_router`] but with a `call_deadline` far beyond the
/// test guard: the cycle-accurate sim backends used by the deadline
/// and cancel tests legitimately hold calls for tens of seconds, and
/// the router's own per-call bound must not race the assertions.
fn start_patient_router(backends: Vec<String>) -> Router {
    let mut cfg = quick_cfg(backends);
    cfg.call_deadline = Duration::from_secs(300);
    Router::start(cfg, &ListenAddr::parse("127.0.0.1:0")).unwrap()
}

/// The chaos gate. Two replicas; one is scripted to drop every
/// connection after 40 frames — the in-process stand-in for `kill -9`
/// mid-burst (`TMFU_FAULT_DROP_AFTER` scripts the same from the CLI,
/// but env vars would fault *both* in-process backends). Every call in
/// a large burst must complete bit-exactly anyway, within the per-call
/// deadline, with zero hangs and a balanced ledger on both the router
/// and the surviving backend.
#[test]
fn chaos_one_replica_dies_mid_burst_and_every_call_still_settles() {
    let (service_a, server_a) = backend(2);
    let (service_b, server_b) = backend(2);
    server_a.ctl().set_fault_plan(FaultPlan {
        drop_after_frames: Some(40),
        ..FaultPlan::default()
    });
    let router = start_router(vec![server_a.addr().to_string(), server_b.addr().to_string()]);
    let client = OverlayClient::connect(&router.addr().to_string()).unwrap();
    assert_eq!(client.backend(), "router");

    let gradient = client.kernel("gradient").unwrap();
    let dfg = service_b.registry().get("gradient").unwrap().dfg.clone();
    const N: usize = 400;
    let mut jobs = Vec::with_capacity(N);
    for i in 0..N as i32 {
        let inputs = vec![i, 5 - i, 2, 7, -i];
        let want = eval(&dfg, &inputs);
        jobs.push((gradient.submit(&inputs).unwrap(), want));
    }
    // Bounded waits: a wedged call fails the test rather than hanging
    // the suite.
    let guard = Instant::now() + Duration::from_secs(60);
    for (i, (mut p, want)) in jobs.into_iter().enumerate() {
        let left = guard.saturating_duration_since(Instant::now());
        let got = p.wait_timeout(left).unwrap_or_else(|e| panic!("call {i}: {e}"));
        assert_eq!(got, want, "call {i} must be bit-exact");
    }

    // Ledger: every admitted call settled exactly once, none failed —
    // the survivor absorbed the retries.
    let m = router.metrics();
    assert_eq!(m.admitted(), N as u64);
    assert_eq!(m.completed(), N as u64);
    assert_eq!(m.failed(), 0);
    assert!(m.retries() > 0, "the scripted fault must actually have bitten");
    assert_eq!(router.ctl().inflight(), 0);
    // The surviving backend is quiescent: nothing leaked in flight.
    assert_eq!(server_b.ctl().inflight(), 0);

    drop(client);
    router.shutdown();
    server_a.shutdown();
    server_b.shutdown();
    service_a.shutdown().unwrap();
    service_b.shutdown().unwrap();
}

/// The PR 10 chaos gate: a cancel storm *and* a replica death in the
/// same burst. Two slow (cycle-accurate sim) replicas are each pinned
/// by a 6144-row batch; 120 singles queue behind them and half are
/// withdrawn with `Cancel` while replica A is scripted to drop every
/// connection after 60 frames. Every surviving call must settle
/// bit-exact, and the ledger must balance **with the cancelled term**
/// at every level: router (`admitted == completed + failed +
/// cancelled`) and both backend services.
#[test]
fn chaos_cancel_storm_with_replica_death_keeps_every_ledger_balanced() {
    // Sim + a tiny worker row budget: the backlog outlives the whole
    // cancel exchange, so a cancelled single is still queued when the
    // withdrawal lands (deterministically `cancelled`, not raced).
    let sim_backend = || {
        let service = Arc::new(
            OverlayService::builder()
                .backend(BackendKind::Sim)
                .pipelines(1)
                .max_batch(4)
                // Deep enough for the survivor to absorb the dead
                // replica's retried pin batch on top of its own.
                .queue_depth(16384)
                .build()
                .unwrap(),
        );
        let server = WireServer::bind(Arc::clone(&service), &ListenAddr::parse("127.0.0.1:0"))
            .unwrap();
        (service, server)
    };
    let (service_a, server_a) = sim_backend();
    let (service_b, server_b) = sim_backend();
    server_a.ctl().set_fault_plan(FaultPlan {
        drop_after_frames: Some(60),
        ..FaultPlan::default()
    });
    let router =
        start_patient_router(vec![server_a.addr().to_string(), server_b.addr().to_string()]);
    let client = OverlayClient::connect(&router.addr().to_string()).unwrap();
    let gradient = client.kernel("gradient").unwrap();
    let dfg = service_b.registry().get("gradient").unwrap().dfg.clone();

    // Pin both replicas (round-robin spreads the two batches).
    let mut pins = Vec::new();
    for salt in 0..2i32 {
        let mut batch = FlatBatch::new(5);
        for i in 0..6144i32 {
            batch.push(&[3, 5 - salt, 2, 7, i]);
        }
        pins.push((gradient.submit_batch(&batch).unwrap(), batch));
    }

    // The burst: 120 singles, every other one withdrawn immediately.
    const N: usize = 120;
    let mut keep = Vec::new();
    let mut victims = Vec::new();
    for i in 0..N as i32 {
        let inputs = vec![i, 5 - i, 2, 7, -i];
        let p = gradient.submit(&inputs).unwrap();
        if i % 2 == 0 {
            keep.push((p, eval(&dfg, &inputs)));
        } else {
            victims.push(p);
        }
    }
    // Let the forward reactor relay the burst downstream before the
    // storm: a victim cancelled *after* dispatch exercises the full
    // wire path (router entry drop -> downstream Cancel -> backend
    // queue removal), not just the cheap pre-dispatch drop.
    std::thread::sleep(Duration::from_millis(100));
    for p in &mut victims {
        p.cancel();
    }

    // Every kept call settles bit-exact despite the replica death.
    let guard = Instant::now() + Duration::from_secs(180);
    for (i, (mut p, want)) in keep.into_iter().enumerate() {
        let left = guard.saturating_duration_since(Instant::now());
        let got = p.wait_timeout(left).unwrap_or_else(|e| panic!("kept call {i}: {e}"));
        assert_eq!(got, want, "kept call {i} must be bit-exact");
    }
    for (i, (p, batch)) in pins.into_iter().enumerate() {
        let out = p.wait().unwrap_or_else(|e| panic!("pin batch {i}: {e}"));
        assert_eq!(out.n_rows(), batch.n_rows());
        for (r, row) in batch.iter().enumerate() {
            assert_eq!(out.row(r), &eval(&dfg, row)[..], "pin {i} row {r}");
        }
    }

    // Router ledger: the cancelled term balances it exactly.
    let m = router.metrics();
    assert_eq!(m.admitted(), (N + 2) as u64);
    assert_eq!(m.cancelled(), (N / 2) as u64);
    assert_eq!(m.completed(), (N / 2 + 2) as u64);
    assert_eq!(m.failed(), 0);
    assert_eq!(m.admitted(), m.completed() + m.failed() + m.cancelled());
    assert_eq!(router.ctl().inflight(), 0);

    // Both backend ledgers balance with their own cancelled terms
    // (the withdrawal propagated downstream as a wire Cancel). Spans
    // abandoned by the faulted connection drain asynchronously — their
    // slots recycle via drop-abandon while the worker still executes
    // the rows — so poll until the books close instead of snapshotting.
    for (name, service) in [("a", &service_a), ("b", &service_b)] {
        let ledger_guard = Instant::now() + Duration::from_secs(90);
        loop {
            let snap = service.metrics();
            if snap.admitted() == snap.completed + snap.failed + snap.cancelled {
                break;
            }
            assert!(
                Instant::now() < ledger_guard,
                "backend {name} ledger never balanced: admitted={} completed={} failed={} \
                 cancelled={}",
                snap.admitted(),
                snap.completed,
                snap.failed,
                snap.cancelled
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let down_cancelled: u64 =
        [&service_a, &service_b].iter().map(|s| s.metrics().cancelled).sum();
    assert!(
        down_cancelled > 0,
        "at least some withdrawals must reach a backend queue as wire Cancels"
    );

    drop(victims);
    drop(client);
    router.shutdown();
    server_a.shutdown();
    server_b.shutdown();
    service_a.shutdown().unwrap();
    service_b.shutdown().unwrap();
}

/// Deadline propagation through the router: a client budget rides the
/// upstream Call frame, the router enforces `min(budget,
/// call_deadline)`, and a miss comes back as the typed
/// `DeadlineExceeded` — counted as `failed` in the router's ledger
/// (it is not retryable, so no retry burns the dead budget).
#[test]
fn client_deadline_propagates_through_the_router_and_fails_typed() {
    let service = Arc::new(
        OverlayService::builder()
            .backend(BackendKind::Sim)
            .pipelines(1)
            .max_batch(4)
            .queue_depth(16384)
            .build()
            .unwrap(),
    );
    let server = WireServer::bind(Arc::clone(&service), &ListenAddr::parse("127.0.0.1:0"))
        .unwrap();
    let router = start_patient_router(vec![server.addr().to_string()]);
    let client = OverlayClient::connect(&router.addr().to_string()).unwrap();
    let gradient = client.kernel("gradient").unwrap();

    // A backlog the 5 ms budget cannot survive.
    let mut backlog = FlatBatch::new(5);
    for i in 0..8192i32 {
        backlog.push(&[3, 5, 2, 7, i]);
    }
    let pin = gradient.submit_batch(&backlog).unwrap();

    let err = gradient
        .call_with_deadline(&[3, 5, 2, 7, 1], Duration::from_millis(5))
        .unwrap_err();
    assert!(
        matches!(err, ServiceError::DeadlineExceeded { .. }),
        "expected DeadlineExceeded through the router, got {err}"
    );

    assert_eq!(pin.wait().unwrap().n_rows(), 8192);
    let m = router.metrics();
    assert_eq!(m.admitted(), m.completed() + m.failed() + m.cancelled());
    assert!(
        m.failed() + m.cancelled() >= 1,
        "the missed deadline must settle in the router ledger"
    );

    drop(client);
    router.shutdown();
    server.shutdown();
    service.shutdown().unwrap();
}

#[test]
fn no_reachable_replica_is_typed_unavailable() {
    // Ports 9/10 on loopback: nobody listens, connects fail fast.
    let router = start_router(vec!["127.0.0.1:9".to_string(), "127.0.0.1:10".to_string()]);
    let client = OverlayClient::connect(&router.addr().to_string()).unwrap();
    let err = client.kernel("gradient").unwrap_err();
    assert!(
        matches!(err, ServiceError::Unavailable { ref kernel } if kernel == "gradient"),
        "expected Unavailable, got {err}"
    );
    drop(client);
    router.shutdown();
}

#[test]
fn batches_health_metrics_and_graceful_drain_work_through_the_router() {
    let (service, server) = backend(2);
    let router = start_router(vec![server.addr().to_string()]);
    let client = OverlayClient::connect(&router.addr().to_string()).unwrap();

    let health = client.health().unwrap();
    assert!(!health.draining);

    // Batches forward atomically and come back row-exact.
    let poly6 = client.kernel("poly6").unwrap();
    let compiled = service.registry().get("poly6").unwrap().clone();
    let mut batch = FlatBatch::new(poly6.arity());
    for i in 0..17i32 {
        batch.push_iter((0..poly6.arity()).map(|j| i * 31 + j as i32));
    }
    let out = poly6.call_batch(&batch).unwrap();
    assert_eq!(out.n_rows(), 17);
    for (i, row) in batch.iter().enumerate() {
        assert_eq!(out.row(i), &eval(&compiled.dfg, row)[..], "row {i}");
    }

    // Metrics name the role and the ledger; one CallBatch admitted.
    let m = client.metrics().unwrap();
    assert_eq!(m.get("role").as_str(), Some("router"));
    assert_eq!(m.get("admitted").as_i64(), Some(1));
    assert_eq!(m.get("completed").as_i64(), Some(1));
    assert_eq!(m.get("backends").at(0).get("up").as_bool(), Some(true));

    // Graceful drain: acknowledged draining, then wait() returns.
    let report = client.drain().unwrap();
    assert!(report.draining);
    router.wait();

    drop(client);
    server.shutdown();
    service.shutdown().unwrap();
}

/// When every replica dies *and stays dead*, calls fail typed — fast,
/// bounded by the retry budget and deadline — and the ledger accounts
/// the failure. No hangs, no untyped errors.
#[test]
fn calls_fail_typed_and_bounded_when_every_replica_stays_dead() {
    let (service, server) = backend(1);
    let mut cfg = quick_cfg(vec![server.addr().to_string()]);
    cfg.call_deadline = Duration::from_secs(3);
    cfg.max_retries = 2;
    let router = Router::start(cfg, &ListenAddr::parse("127.0.0.1:0")).unwrap();
    let client = OverlayClient::connect(&router.addr().to_string()).unwrap();
    let gradient = client.kernel("gradient").unwrap();
    assert_eq!(gradient.call(&[3, 5, 2, 7, 1]).unwrap(), vec![36]);

    // The only backend goes away for good.
    server.shutdown();
    service.shutdown().unwrap();

    let t0 = Instant::now();
    let err = gradient.call(&[3, 5, 2, 7, 1]).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(10), "took {:?}", t0.elapsed());
    assert!(
        matches!(
            err,
            ServiceError::Unavailable { .. }
                | ServiceError::Disconnected { .. }
                | ServiceError::ShutDown
                | ServiceError::DeadlineExceeded { .. }
        ),
        "expected a typed environmental error, got {err}"
    );

    let m = router.metrics();
    assert_eq!(m.admitted(), 2);
    assert_eq!(m.completed(), 1);
    assert_eq!(m.failed(), 1);
    assert_eq!(router.ctl().inflight(), 0);

    drop(client);
    router.shutdown();
}
