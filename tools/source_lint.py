#!/usr/bin/env python3
"""Textual lint gates for the concurrent runtime (DESIGN.md §12).

Rules — scoped to the directories where the invariants actually bite
(`rust/src/wire/`, `rust/src/router/`, `rust/src/coordinator/`):

1. **relaxed-ok**: every `Ordering::Relaxed` must carry a
   `relaxed-ok:` annotation (same line, or in the contiguous run of
   comment/`Relaxed` lines immediately above it) explaining why the
   weakest ordering is sufficient. Ledger/inflight counters must use
   Release/Acquire; un-annotated Relaxed is how they silently regress.

2. **no poisoning panics**: `.lock().unwrap()` / `.lock().expect(` are
   banned — one panicked thread must not cascade through every later
   locker. Use `crate::util::sync::LockExt::lock_unpoisoned()`.

3. **checked casts in the frame codec**: in `rust/src/wire/mod.rs`
   (codec proper, up to `mod tests`), bare `as` numeric casts are
   banned unless annotated `cast-ok:` (same line or the line above).
   Decode paths must use `try_from`/`usize::from` so a hostile length
   prefix cannot silently truncate. (`clippy::cast_possible_truncation`
   warns on the narrowing subset; this rule also covers widening casts
   so every remaining `as` carries its justification.)

Exit status: 0 clean, 1 with findings (one line each:
`path:line: rule: message`).

Usage: python3 tools/source_lint.py [--root DIR]
"""

import argparse
import os
import re
import sys

LINT_DIRS = ("rust/src/wire", "rust/src/router", "rust/src/coordinator")

RELAXED = re.compile(r"Ordering::Relaxed")
RELAXED_OK = re.compile(r"relaxed-ok:")
LOCK_UNWRAP = re.compile(r"\.lock\(\)\s*\.\s*(unwrap|expect)\s*\(")
NUMERIC_CAST = re.compile(
    r"\bas\s+(u8|u16|u32|u64|u128|usize|i8|i16|i32|i64|i128|isize|f32|f64)\b"
)
CAST_OK = re.compile(r"cast-ok:")
COMMENT = re.compile(r"^\s*//")


def relaxed_is_annotated(lines, i):
    """`lines[i]` contains Ordering::Relaxed. Annotated iff the line
    itself, or any comment in the contiguous run of comment/Relaxed
    lines directly above it, says `relaxed-ok:`."""
    if RELAXED_OK.search(lines[i]):
        return True
    j = i - 1
    while j >= 0 and (COMMENT.match(lines[j]) or RELAXED.search(lines[j])):
        if RELAXED_OK.search(lines[j]):
            return True
        j -= 1
    return False


def cast_is_annotated(lines, i):
    if CAST_OK.search(lines[i]):
        return True
    return i > 0 and CAST_OK.search(lines[i - 1]) is not None


def lint_file(path, rel, findings):
    with open(path, encoding="utf-8") as f:
        lines = f.read().split("\n")

    codec_end = len(lines)  # rule 3 stops at the test module
    if rel == "rust/src/wire/mod.rs":
        for i, line in enumerate(lines):
            if line.startswith("mod tests {"):
                codec_end = i
                break

    for i, line in enumerate(lines):
        if RELAXED.search(line) and not relaxed_is_annotated(lines, i):
            findings.append(
                f"{rel}:{i + 1}: relaxed-ordering: Ordering::Relaxed without a "
                "`relaxed-ok:` justification (ledger/inflight counters need "
                "Release/Acquire)"
            )
        if LOCK_UNWRAP.search(line):
            findings.append(
                f"{rel}:{i + 1}: lock-unwrap: .lock().unwrap()/.expect() "
                "cascades poison; use util::sync::LockExt::lock_unpoisoned()"
            )
        if (
            rel == "rust/src/wire/mod.rs"
            and i < codec_end
            and NUMERIC_CAST.search(line)
            and not cast_is_annotated(lines, i)
        ):
            findings.append(
                f"{rel}:{i + 1}: bare-cast: `as` numeric cast in the frame "
                "codec without a `cast-ok:` annotation (use try_from / "
                "usize::from)"
            )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=".", help="repository root")
    args = ap.parse_args()

    findings = []
    scanned = 0
    for d in LINT_DIRS:
        base = os.path.join(args.root, d)
        if not os.path.isdir(base):
            print(f"source_lint: missing directory {d}", file=sys.stderr)
            return 2
        for dirpath, _, names in os.walk(base):
            for name in sorted(names):
                if not name.endswith(".rs"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, args.root).replace(os.sep, "/")
                lint_file(path, rel, findings)
                scanned += 1

    for f in findings:
        print(f)
    if findings:
        print(f"source_lint: {len(findings)} finding(s) in {scanned} file(s)")
        return 1
    print(f"source_lint: clean ({scanned} files scanned)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
