#!/usr/bin/env python3
"""Assert the serving-perf invariants recorded in a bench_perf JSON.

Usage: bench_smoke_check.py <fresh.json> [<committed-baseline.json>]

Hard gates (fail the build):
  * ``submit_allocs_per_call`` must be exactly 0 — the completion
    slab's steady-state submit -> wait path is allocation-free, audited
    with a thread-local allocation counter (bench_perf section B6).
  * ``worker_allocs_per_batch`` must be exactly 0 — the worker loop's
    take -> gather -> execute_into -> reply path is allocation-free in
    steady state, audited via per-worker thread-local counters
    published through the engine metrics (bench_perf section B6).
  * ``peak_threads_10k_inflight`` (when measured — Linux) must stay
    O(workers + connections): a value scaling with the in-flight count
    means the wire reactor regressed to thread-per-call.
  * ``turbo_speedup_vs_ref`` must meet its recorded floor (raised to
    20x for the SIMD-lowered interpreter in PR 6), when both numbers
    are present.
  * ``router_call_overhead_us`` (the extra cost of the `tmfu router`
    store-and-forward hop over a direct wire call, bench_perf section
    B7) must stay within 3x of the same run's wire framing overhead —
    one extra hop should cost about one extra framing pass, so 3x (or
    a 150us absolute floor, whichever is larger, to absorb fast-mode
    noise) catches a regression to blocking forwarding or per-call
    threads.
  * ``fair_tenant_p99_under_abuse_us`` (bench_perf section B8): the
    polite tenant's p99 while a greedy tenant floods the service must
    stay under half the flooder's own mean latency (or a 500us
    absolute floor, whichever is larger) — under FIFO the polite p99
    would *exceed* the flooder's mean, so this catches any regression
    of the weighted DRR scheduler. ``fair_tenant_rejections`` must be
    exactly 0: fairness must come from scheduling, never from shedding
    the well-behaved tenant's load.

  * ``shed_under_overload_p99_us`` (bench_perf section B9): the p99
    latency of a *typed deadline refusal* under a 64k-row single-worker
    overload must stay under half the same run's unbudgeted backlog
    wait (``no_shed_overload_wait_us``), with a 1000us absolute floor
    for fast-mode noise. Shedding exists so an overloaded caller hears
    "no" in microseconds instead of queueing for the full backlog — if
    the refusal costs anything like the wait it replaces, admission
    feasibility or lazy expiry regressed to executing doomed work.
    ``cancel_reclaim_us`` must also be recorded and stay under 1000us
    per call: withdrawing a queued request is a synchronous slab-slot
    release plus a queue purge, never a drain of the backlog.

Soft gate:
  * ``wire_call_overhead_us`` is compared against the committed
    baseline JSON when that file carries a *measured* number (cargo
    harness). Fast-mode smoke numbers are noisy, so the bound is a
    3x margin — catching an order-of-magnitude regression (e.g. a
    reintroduced per-call thread spawn), not jitter. When the
    committed baseline has no measured value (authored offline), the
    check reports and passes.
"""

import json
import sys


def fail(msg: str) -> None:
    print(f"bench-smoke: FAIL — {msg}", file=sys.stderr)
    sys.exit(1)


def main() -> None:
    if len(sys.argv) < 2:
        fail(f"usage: {sys.argv[0]} <fresh.json> [<committed-baseline.json>]")
    with open(sys.argv[1]) as f:
        fresh = json.load(f)
    meta = fresh.get("meta", {})

    allocs = meta.get("submit_allocs_per_call")
    if allocs is None:
        fail("submit_allocs_per_call missing from the bench JSON (B6 did not run)")
    if allocs != 0:
        fail(f"submit_allocs_per_call = {allocs}, must be exactly 0")
    print("bench-smoke: submit_allocs_per_call == 0 (allocation-free submit path)")

    worker_allocs = meta.get("worker_allocs_per_batch")
    if worker_allocs is None:
        fail("worker_allocs_per_batch missing from the bench JSON (B6 worker audit did not run)")
    if worker_allocs != 0:
        fail(f"worker_allocs_per_batch = {worker_allocs}, must be exactly 0")
    print("bench-smoke: worker_allocs_per_batch == 0 (allocation-free worker loop)")

    peak = meta.get("peak_threads_10k_inflight")
    if peak is None:
        print("bench-smoke: peak thread count not measured on this platform (skipped)")
    elif peak >= 32:
        fail(f"peak_threads_10k_inflight = {peak} — reactor regressed to thread-per-call")
    else:
        print(f"bench-smoke: {peak} peak threads with 10k calls in flight (bound 32)")

    speedup = meta.get("turbo_speedup_vs_ref")
    floor = meta.get("turbo_speedup_floor")
    if speedup is not None and floor is not None:
        if speedup < floor:
            fail(f"turbo speedup {speedup:.1f}x below the {floor}x floor")
        print(f"bench-smoke: turbo speedup {speedup:.1f}x (floor {floor}x)")

    fresh_wire = meta.get("wire_call_overhead_us")
    router = meta.get("router_call_overhead_us")
    if router is None:
        fail("router_call_overhead_us missing from the bench JSON (B7 did not run)")
    if isinstance(fresh_wire, (int, float)) and fresh_wire > 0:
        bound = max(3.0 * fresh_wire, 150.0)
        if router > bound:
            fail(
                f"router_call_overhead_us = {router:.1f}us vs wire framing overhead "
                f"{fresh_wire:.1f}us (bound {bound:.1f}us) — the forwarding hop regressed"
            )
        print(
            f"bench-smoke: router_call_overhead_us {router:.1f}us vs wire "
            f"{fresh_wire:.1f}us (within bound {bound:.1f}us)"
        )
    else:
        print(f"bench-smoke: router_call_overhead_us {router:.1f}us recorded")

    fair_p99 = meta.get("fair_tenant_p99_under_abuse_us")
    if fair_p99 is None:
        fail("fair_tenant_p99_under_abuse_us missing from the bench JSON (B8 did not run)")
    fair_rejections = meta.get("fair_tenant_rejections", 0)
    if fair_rejections != 0:
        fail(
            f"fair_tenant_rejections = {fair_rejections} — the fair tenant was "
            "load-shed instead of scheduled"
        )
    abusive_mean = meta.get("abusive_tenant_mean_us")
    if isinstance(abusive_mean, (int, float)) and abusive_mean > 0:
        bound = max(0.5 * abusive_mean, 500.0)
        if fair_p99 > bound:
            fail(
                f"fair_tenant_p99_under_abuse_us = {fair_p99:.1f}us vs abusive mean "
                f"{abusive_mean:.1f}us (bound {bound:.1f}us) — DRR isolation regressed"
            )
        print(
            f"bench-smoke: fair-tenant p99 {fair_p99:.1f}us vs abusive mean "
            f"{abusive_mean:.1f}us (within bound {bound:.1f}us, 0 rejections)"
        )
    else:
        print(f"bench-smoke: fair-tenant p99 {fair_p99:.1f}us recorded (0 rejections)")

    shed_p99 = meta.get("shed_under_overload_p99_us")
    if shed_p99 is None:
        fail("shed_under_overload_p99_us missing from the bench JSON (B9 did not run)")
    no_shed = meta.get("no_shed_overload_wait_us")
    if isinstance(no_shed, (int, float)) and no_shed > 0:
        bound = max(0.5 * no_shed, 1000.0)
        if shed_p99 > bound:
            fail(
                f"shed_under_overload_p99_us = {shed_p99:.1f}us vs no-shed backlog wait "
                f"{no_shed:.1f}us (bound {bound:.1f}us) — deadline shedding regressed to "
                "waiting out the overload"
            )
        print(
            f"bench-smoke: overload shed p99 {shed_p99:.1f}us vs no-shed wait "
            f"{no_shed:.1f}us (within bound {bound:.1f}us)"
        )
    else:
        print(f"bench-smoke: overload shed p99 {shed_p99:.1f}us recorded")
    reclaim = meta.get("cancel_reclaim_us")
    if reclaim is None:
        fail("cancel_reclaim_us missing from the bench JSON (B9 cancel audit did not run)")
    if reclaim > 1000.0:
        fail(
            f"cancel_reclaim_us = {reclaim:.1f}us per call — slot reclaim must be a "
            "synchronous release, not a backlog drain"
        )
    print(f"bench-smoke: cancel reclaim {reclaim:.2f}us per call (bound 1000us)")

    baseline_wire = None
    if len(sys.argv) > 2:
        try:
            with open(sys.argv[2]) as f:
                baseline_wire = json.load(f).get("meta", {}).get("wire_call_overhead_us")
        except FileNotFoundError:
            baseline_wire = None
    if fresh_wire is None:
        fail("wire_call_overhead_us missing from the bench JSON (B5 did not run)")
    if isinstance(baseline_wire, (int, float)) and baseline_wire > 0:
        bound = 3.0 * baseline_wire
        if fresh_wire > bound:
            fail(
                f"wire_call_overhead_us = {fresh_wire:.1f}us vs committed baseline "
                f"{baseline_wire:.1f}us (bound {bound:.1f}us) — wire per-call path regressed"
            )
        print(
            f"bench-smoke: wire_call_overhead_us {fresh_wire:.1f}us vs baseline "
            f"{baseline_wire:.1f}us (within 3x)"
        )
    else:
        print(
            f"bench-smoke: wire_call_overhead_us {fresh_wire:.1f}us recorded "
            "(no measured committed baseline to compare against yet)"
        )
    print("bench-smoke: OK")


if __name__ == "__main__":
    main()
