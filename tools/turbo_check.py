#!/usr/bin/env python3
"""Offline mirror of the turbo backend's tape compiler + interpreter.

Replicates, decision for decision, ``exec::Tape::compile`` (stage-walk
slot assignment: inputs first, constants on first use, one fresh slot
per op) and the lane-chunked executor in ``exec::tape::execute_into``
(LANES-wide blocks, stale garbage lanes computed-and-discarded, consts
loaded once per call), then asserts against the functional oracle:

  * bit-exact agreement on every benchmark kernel for random packets,
    wrapping corners (``i32::MIN``, ``(1 << 17)²``) and batch sizes
    that straddle the lane-chunk boundary;
  * bit-exact agreement on the *same fuzzed kernel stream* the Rust
    test ``fuzz_turbo_tape_against_oracle`` draws (xoshiro256** seed
    0x7EA7, case ids 3000+, identical draw order), including the
    invariant that compilation only ever fails with RF/IM overflow;
  * slot indices strictly increase along the tape (the race-freedom
    property the Rust interpreter's split-borrow relies on).

With ``--json <path>`` it also measures the mirror interpreters and
writes a perf-trajectory file in the same shape as
``util::bench::BenchReport`` — the toolchain-free stand-in for
``make bench`` (``meta.harness`` records which harness produced the
numbers; regenerate with ``make bench`` when a cargo toolchain is
available).

Run before shipping tape/backend changes when no Rust toolchain is
available:  python3 tools/turbo_check.py [--json BENCH_PR2.json]
"""

import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from gen_dfg_json import (  # noqa: E402
    KERNELS,
    Parser,
    SRC_DIR,
    apply_op,
    evaluate,
    lower,
    normalize,
    schedule,
    timing,
    tokenize,
    wrap32,
)
from fuzz_check import Rng, random_kernel_source  # noqa: E402
from sim_check import Pipeline  # noqa: E402

LANES = 16  # exec::tape::LANES
I32_MIN = -(2**31)
I32_MAX = 2**31 - 1


# ---------------------------------------------------------------------
# Tape mirror
# ---------------------------------------------------------------------

def tape_compile(nodes, stages):
    """Mirror of Tape::compile: returns (ops, consts, outputs, n_inputs,
    n_slots) with ops as (opname, a_slot, b_slot, dst_slot)."""
    slot = {}
    nxt = 0
    input_ids = [i for i, n in enumerate(nodes) if n["kind"] == "input"]
    for i in input_ids:
        slot[i] = nxt
        nxt += 1
    consts, ops = [], []
    for st in stages:
        for op_id in st["ops"]:
            n = nodes[op_id]
            assert n["kind"] == "op"
            arg_slots = []
            for a in n["args"]:
                if a in slot:
                    arg_slots.append(slot[a])
                else:
                    assert nodes[a]["kind"] == "const", f"operand {a} unproduced"
                    slot[a] = nxt
                    consts.append((nxt, nodes[a]["value"]))
                    arg_slots.append(nxt)
                    nxt += 1
            dst = nxt
            nxt += 1
            slot[op_id] = dst
            assert arg_slots[0] < dst and arg_slots[1] < dst
            ops.append((n["op"], arg_slots[0], arg_slots[1], dst))
    assert ops, "tape with no operations"
    outputs = []
    for i, n in enumerate(nodes):
        if n["kind"] == "output":
            src = n["args"][0]
            if src not in slot:
                # Mirror of the Rust fallback: a const emitted directly
                # as an output gets a preloaded slot (unreachable via
                # Program::schedule today, but lowering stays total).
                assert nodes[src]["kind"] == "const", f"output reads unproduced {src}"
                slot[src] = nxt
                consts.append((nxt, nodes[src]["value"]))
                nxt += 1
            outputs.append(slot[src])
    return ops, consts, outputs, len(input_ids), nxt


def tape_execute(tape, rows):
    """Mirror of execute_into: lane-chunked, stale lanes computed and
    discarded, consts loaded once per call."""
    ops, consts, outputs, n_in, n_slots = tape
    scratch = [0] * (n_slots * LANES)
    for s, v in consts:
        for l in range(LANES):
            scratch[s * LANES + l] = v
    out = []
    row = 0
    n = len(rows)
    while row < n:
        chunk = min(LANES, n - row)
        for i in range(n_in):
            for l in range(chunk):
                scratch[i * LANES + l] = rows[row + l][i]
        for opname, a, b, dst in ops:
            for l in range(LANES):  # full LANES: garbage lanes wrap safely
                scratch[dst * LANES + l] = apply_op(
                    opname, scratch[a * LANES + l], scratch[b * LANES + l]
                )
        for l in range(chunk):
            out.append([scratch[s * LANES + l] for s in outputs])
        row += chunk
    return out


def compile_kernel_source(src):
    kname, params, body, returns = Parser(tokenize(src)).kernel()
    nodes = normalize(lower(kname, params, body, returns))
    return nodes


# ---------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------

def check_benchmarks():
    import random

    rng = random.Random(0x7A9E)
    for name in KERNELS:
        with open(os.path.join(SRC_DIR, f"{name}.k")) as f:
            nodes = compile_kernel_source(f.read())
        stages, _, _ = schedule(name, nodes)
        tape = tape_compile(nodes, stages)
        n_in = tape[3]
        n_ops = sum(1 for n in nodes if n["kind"] == "op")
        assert len(tape[0]) == n_ops, f"{name}: tape len {len(tape[0])} != ops {n_ops}"
        rows = [
            [rng.randrange(I32_MIN, I32_MAX + 1) for _ in range(n_in)] for _ in range(53)
        ]
        rows.append([I32_MIN] * n_in)
        rows.append([1 << 17] * n_in)
        rows.append([I32_MAX if i % 2 == 0 else -1 for i in range(n_in)])
        got = tape_execute(tape, rows)
        for pkt, o in zip(rows, got):
            want = evaluate(nodes, pkt)
            assert o == want, f"{name}: {pkt} -> {o}, oracle {want}"
        print(f"{name:<10} tape ok: {len(tape[0])} ops, {tape[4]} slots, 56 packets bit-exact")


def check_fuzz_stream():
    """Replay rust/tests/integration.rs::fuzz_turbo_tape_against_oracle:
    same PRNG, same draw order, same invariants."""
    rng = Rng(0x7EA7)
    tested = 0
    for case in range(50):
        src = random_kernel_source(rng, 3000 + case)
        try:
            nodes = compile_kernel_source(src)
        except Exception as e:  # the Rust frontend accepts these; mirror must too
            raise AssertionError(f"case {case}: mirror frontend failed: {e}\n{src}")
        if sum(1 for n in nodes if n["kind"] == "op") == 0:
            continue
        try:
            stages, _, _ = schedule(f"rand{3000 + case}", nodes)
        except AssertionError as e:
            assert "overflow" in str(e), f"case {case}: non-overflow failure: {e}\n{src}"
            continue
        tape = tape_compile(nodes, stages)
        n_in = tape[3]
        rows = [[I32_MIN] * n_in, [1 << 17] * n_in]
        for _ in range(21):
            rows.append([wrap32(rng.next_u64() >> 32) for _ in range(n_in)])
        got = tape_execute(tape, rows)
        for pkt, o in zip(rows, got):
            want = evaluate(nodes, pkt)
            assert o == want, f"case {case}: {pkt} -> {o}, oracle {want}\n{src}"
        tested += 1
    assert tested >= 30, f"only {tested} fuzz cases exercised"
    print(f"fuzz mirror: {tested}/50 cases pass (tape vs oracle, overflow-only failures)")


# ---------------------------------------------------------------------
# Bench mode (--json): the toolchain-free perf trajectory stand-in
# ---------------------------------------------------------------------

def measure(name, items_per_iter, fn, min_iters=5, min_time_s=0.5):
    times = []
    t_end = time.perf_counter() + min_time_s
    while len(times) < min_iters or time.perf_counter() < t_end:
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e9)
    times.sort()
    mean = sum(times) / len(times)
    m = {
        "name": name,
        "iters": len(times),
        "mean_ns": mean,
        "p50_ns": times[len(times) // 2],
        "min_ns": times[0],
        "items_per_iter": float(items_per_iter),
        "items_per_s": items_per_iter / (mean * 1e-9),
    }
    print(
        f"{name:<44} {mean / 1e6:10.3f} ms/iter  "
        f"{m['items_per_s'] / 1e3:10.1f} kitems/s  (n={len(times)})"
    )
    return m


def bench(json_path):
    import random

    try:
        import numpy as np
    except ImportError:
        # Refuse to write a baseline with no turbo measurements (the
        # speedup would read 0.0 and the CI floor check would reject
        # the next push) — fail loudly instead.
        sys.exit("turbo_check --json needs numpy for the turbo mirror; none found")
    rng = random.Random(3)
    batch = 1024
    measurements = []
    headline = {}
    for name in ["gradient", "chebyshev", "poly6", "qspline"]:
        with open(os.path.join(SRC_DIR, f"{name}.k")) as f:
            nodes = compile_kernel_source(f.read())
        stages, output_order, _ = schedule(name, nodes)
        ii, _ = timing(stages)
        tape = tape_compile(nodes, stages)
        n_in = tape[3]
        rows = [
            [rng.randrange(I32_MIN, I32_MAX + 1) for _ in range(n_in)]
            for _ in range(batch)
        ]
        # ref mirror: per-packet node walk (what RefBackend does).
        m = measure(
            f"ref::execute({name}, batch {batch})",
            batch,
            lambda: [evaluate(nodes, r) for r in rows],
            min_time_s=0.3,
        )
        measurements.append(m)
        headline[f"ref:{name}"] = m["items_per_s"]
        # turbo mirror: the same tape, lanes = whole batch via numpy
        # (the vectorization the Rust lane loops hand to LLVM).
        ops, consts, outputs, _, n_slots = tape
        arr = np.array(rows, dtype=np.int32)  # [batch][n_in]
        def turbo_run():
            slots = np.empty((n_slots, batch), dtype=np.int32)
            for i in range(n_in):
                slots[i] = arr[:, i]
            for s, v in consts:
                slots[s] = v
            with np.errstate(over="ignore"):
                for opname, a, b, dst in ops:
                    if opname == "add":
                        slots[dst] = slots[a] + slots[b]
                    elif opname == "sub":
                        slots[dst] = slots[a] - slots[b]
                    elif opname == "mul":
                        slots[dst] = slots[a] * slots[b]
                    elif opname == "and":
                        slots[dst] = slots[a] & slots[b]
                    elif opname == "or":
                        slots[dst] = slots[a] | slots[b]
                    else:
                        slots[dst] = slots[a] ^ slots[b]
            return slots[outputs]
        # cross-check the vectorized mirror before timing it
        out = turbo_run()
        for i in range(0, batch, 137):
            want = evaluate(nodes, rows[i])
            got = [int(out[j, i]) for j in range(len(outputs))]
            assert got == want, f"{name}: numpy mirror diverged at row {i}"
        m = measure(
            f"turbo::execute({name}, batch {batch})", batch, turbo_run, min_time_s=0.3
        )
        measurements.append(m)
        headline[f"turbo:{name}"] = m["items_per_s"]
        # sim mirror cycles/s (64 packets through the cycle-accurate
        # python pipeline).
        sim_rows = [[k] * n_in for k in range(64)]
        probe = Pipeline(nodes, stages, output_order, ii)
        probe.run(sim_rows, 1_000_000)
        cycles = probe.cycle
        def sim_run():
            Pipeline(nodes, stages, output_order, ii).run(sim_rows, 1_000_000)
        measurements.append(
            measure(f"sim::cycles({name}, 64 packets)", cycles, sim_run, min_time_s=0.3)
        )
    speedup = 0.0
    if "turbo:poly6" in headline and headline.get("ref:poly6"):
        speedup = headline["turbo:poly6"] / headline["ref:poly6"]
    report = {
        "meta": {
            "harness": (
                "tools/turbo_check.py (python mirror interpreters; the offline "
                "image ships no cargo — regenerate with `make bench` for "
                "cargo-bench numbers; same tape/ref/sim algorithms either way)"
            ),
            "batch": batch,
            "fast_mode": "0",
            "headline_kernel": "poly6",
            "turbo_speedup_vs_ref": speedup,
            "turbo_speedup_floor": 10.0,
        },
        "measurements": measurements,
    }
    with open(json_path, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    print(
        f"\nheadline: turbo/ref on poly6 @ {batch} = {speedup:.1f}x "
        f"(floor 10x: {'PASS' if speedup >= 10.0 else 'MISS'})"
    )
    print(f"wrote {json_path}")


def main():
    check_benchmarks()
    check_fuzz_stream()
    print("\ntape mirror matches the functional oracle everywhere")
    if "--json" in sys.argv:
        bench(sys.argv[sys.argv.index("--json") + 1])


if __name__ == "__main__":
    main()
