#!/usr/bin/env bash
# Loopback smoke for the wire protocol: `tmfu listen` on a Unix socket
# in one process, `tmfu call` in another, asserting the kernel result
# and a metrics fetch. Run by `make wire-smoke` (part of `make verify`).
set -euo pipefail

BIN=${BIN:-target/release/tmfu}
SOCK=$(mktemp -u "${TMPDIR:-/tmp}/tmfu-wire-smoke-XXXXXX.sock")

cleanup() {
    [ -n "${LPID:-}" ] && kill "$LPID" 2>/dev/null || true
    rm -f "$SOCK"
}
trap cleanup EXIT

# Terminal 1 of the README walkthrough: unix-only listener that exits
# after one connection (so the smoke terminates by itself).
"$BIN" listen --socket "$SOCK" --tcp= --backend turbo --max-conns 1 &
LPID=$!

# The socket file appearing is the readiness signal.
for _ in $(seq 1 200); do
    [ -S "$SOCK" ] && break
    kill -0 "$LPID" 2>/dev/null || { echo "wire smoke: listener died early"; exit 1; }
    sleep 0.05
done
[ -S "$SOCK" ] || { echo "wire smoke: socket never appeared"; exit 1; }

# Terminal 2: one call (gradient(3,5,2,7,1) = 36) plus a metrics fetch.
OUT=$("$BIN" call gradient --addr "unix:$SOCK" --inputs 3,5,2,7,1 --metrics)
echo "$OUT"

echo "$OUT" | head -n 1 | grep -qx "36" \
    || { echo "wire smoke: expected result 36"; exit 1; }
echo "$OUT" | grep -q '"completed": 1' \
    || { echo "wire smoke: metrics JSON missing completed=1"; exit 1; }
echo "$OUT" | grep -q '"backend": "turbo"' \
    || { echo "wire smoke: metrics JSON missing backend"; exit 1; }

# The listener exits cleanly after its one connection.
wait "$LPID"
LPID=""
echo "wire smoke: OK (call + metrics over unix:$SOCK)"
