#!/usr/bin/env python3
"""Toolchain-free mirror of the tmfu wire protocol codec (DESIGN.md §9).

Independently implements the byte layout normatively specified in
docs/PROTOCOL.md and checks it against the same golden vectors that the
Rust unit test `wire::tests::golden_bytes_match_the_spec` asserts. If
either implementation drifts from the spec, its golden check fails —
the two implementations never share code, only the table below.

Usage:
  python3 tools/wire_check.py            # verify goldens + round-trip
  python3 tools/wire_check.py --emit     # print the golden table (hex)
"""

import hashlib
import hmac as hmac_mod
import random
import struct
import sys

MAGIC = b"TMFU"

TOKEN_MAC_LEN = 32
# An anonymous Hello body: head (9) + magic (4) + min/max (4).
ANON_HELLO_LEN = 17

OP_HELLO = 0x01
OP_HELLO_OK = 0x02
OP_RESOLVE = 0x03
OP_KERNEL_INFO = 0x04
OP_CALL = 0x05
OP_CALL_BATCH = 0x06
OP_REPLY = 0x07
OP_ERROR = 0x08
OP_GET_METRICS = 0x09
OP_METRICS = 0x0A
OP_HEALTH = 0x0B
OP_HEALTH_OK = 0x0C
OP_DRAIN = 0x0D
OP_CANCEL = 0x0E

HEALTH_SERVING = 0
HEALTH_DRAINING = 1

EC = {
    "unknown_kernel": 1,
    "shape_mismatch": 2,
    "empty_batch": 3,
    "rejected": 4,
    "shut_down": 5,
    "deadline_exceeded": 6,
    "disconnected": 7,
    "backend": 8,
    "unavailable": 9,
    "invalid_kernel": 10,
    "version_mismatch": 100,
    "malformed": 101,
    "unauthorized": 102,
}


def u16(v):
    return struct.pack("<H", v)


def u32(v):
    return struct.pack("<I", v)


def u64(v):
    return struct.pack("<Q", v)


def string(s):
    raw = s.encode("utf-8")
    return u32(len(raw)) + raw


def words(ws):
    return b"".join(struct.pack("<i", w) for w in ws)


def head(opcode, rid):
    return bytes([opcode]) + u64(rid)


def batch(arity, rows):
    """rows: list of lists, each of length arity."""
    flat = [w for r in rows for w in r]
    assert len(flat) == arity * len(rows)
    return u16(arity) + u32(len(rows)) + words(flat)


def token_mac(tenant, secret, nonce):
    """HMAC-SHA256 over tenant bytes || nonce (LE), per PROTOCOL.md."""
    msg = tenant.encode("utf-8") + u64(nonce)
    return hmac_mod.new(secret, msg, hashlib.sha256).digest()


def enc_hello(rid, lo, hi, token=None):
    """token: optional (tenant, secret, nonce) triple — the v2 tenant
    suffix. Anonymous Hellos simply omit it."""
    body = head(OP_HELLO, rid) + MAGIC + u16(lo) + u16(hi)
    if token is not None:
        tenant, secret, nonce = token
        body += string(tenant) + u64(nonce) + token_mac(tenant, secret, nonce)
    return body


def dec_hello(body):
    """Mirror decoder for Hello: returns (rid, lo, hi, tenant, nonce,
    mac) with the token fields None for an anonymous Hello. Raises on
    anything the Rust codec would refuse as Malformed."""
    assert body[0] == OP_HELLO
    (rid,) = struct.unpack_from("<Q", body, 1)
    assert body[9:13] == MAGIC, "bad magic"
    lo, hi = struct.unpack_from("<HH", body, 13)
    if len(body) == ANON_HELLO_LEN:
        return rid, lo, hi, None, None, None
    (tlen,) = struct.unpack_from("<I", body, 17)
    tenant = body[21 : 21 + tlen].decode("utf-8")
    assert len(body) >= 21 + tlen + 8 + TOKEN_MAC_LEN, "truncated token"
    (nonce,) = struct.unpack_from("<Q", body, 21 + tlen)
    mac = body[29 + tlen : 29 + tlen + TOKEN_MAC_LEN]
    assert len(body) == 29 + tlen + TOKEN_MAC_LEN, "trailing bytes"
    return rid, lo, hi, tenant, nonce, mac


def enc_hello_ok(rid, version, backend):
    return head(OP_HELLO_OK, rid) + u16(version) + string(backend)


def enc_resolve(rid, name):
    return head(OP_RESOLVE, rid) + string(name)


def enc_kernel_info(rid, kernel, n_in, n_out):
    return head(OP_KERNEL_INFO, rid) + u32(kernel) + u16(n_in) + u16(n_out)


def enc_call(rid, kernel, inputs, deadline_us=None):
    """deadline_us: optional relative budget (v2 trailing suffix) — a
    deadline-free Call stays byte-identical to v1."""
    body = head(OP_CALL, rid) + u32(kernel) + u16(len(inputs)) + words(inputs)
    if deadline_us is not None:
        body += u64(deadline_us)
    return body


def dec_call(body):
    """Mirror decoder for Call: returns (rid, kernel, inputs,
    deadline_us) with deadline_us None when the optional suffix is
    absent. A partial suffix (any cut strictly inside the 8 bytes) is
    refused, exactly like the Rust codec's Malformed."""
    assert body[0] == OP_CALL
    (rid,) = struct.unpack_from("<Q", body, 1)
    (kernel,) = struct.unpack_from("<I", body, 9)
    (arity,) = struct.unpack_from("<H", body, 13)
    end = 15 + 4 * arity
    assert len(body) >= end, "truncated inputs"
    inputs = [
        struct.unpack_from("<i", body, 15 + 4 * i)[0] for i in range(arity)
    ]
    if len(body) == end:
        return rid, kernel, inputs, None
    assert len(body) == end + 8, "partial deadline suffix"
    (deadline_us,) = struct.unpack_from("<Q", body, end)
    return rid, kernel, inputs, deadline_us


def enc_call_batch(rid, kernel, arity, rows, deadline_us=None):
    body = head(OP_CALL_BATCH, rid) + u32(kernel) + batch(arity, rows)
    if deadline_us is not None:
        body += u64(deadline_us)
    return body


def enc_reply(rid, arity, rows):
    return head(OP_REPLY, rid) + batch(arity, rows)


def enc_error(rid, code, *fields):
    body = head(OP_ERROR, rid) + u16(EC[code])
    if code in (
        "unknown_kernel",
        "empty_batch",
        "deadline_exceeded",
        "disconnected",
        "unavailable",
    ):
        (kernel,) = fields
        body += string(kernel)
    elif code == "shape_mismatch":
        kernel, expected, got = fields
        body += string(kernel) + u32(expected) + u32(got)
    elif code == "rejected":
        kernel, tenant, queued, limit = fields
        body += string(kernel) + string(tenant) + u64(queued) + u64(limit)
    elif code == "shut_down":
        assert not fields
    elif code == "backend":
        backend, message = fields
        body += string(backend) + string(message)
    elif code == "invalid_kernel":
        kernel, detail = fields
        body += string(kernel) + string(detail)
    elif code == "version_mismatch":
        lo, hi = fields
        body += u16(lo) + u16(hi)
    elif code == "malformed":
        (message,) = fields
        body += string(message)
    elif code == "unauthorized":
        (message,) = fields
        body += string(message)
    return body


def enc_get_metrics(rid):
    return head(OP_GET_METRICS, rid)


def enc_metrics(rid, json_text):
    return head(OP_METRICS, rid) + string(json_text)


def enc_health(rid):
    return head(OP_HEALTH, rid)


def enc_health_ok(rid, status, inflight):
    return head(OP_HEALTH_OK, rid) + bytes([status]) + u32(inflight)


def enc_drain(rid):
    return head(OP_DRAIN, rid)


def enc_cancel(rid):
    return head(OP_CANCEL, rid)


# The golden table: (label, payload bytes). Must stay in sync with
# wire::tests::golden_bytes_match_the_spec — same frames, same order.
GOLDEN = [
    ("hello", enc_hello(0, 1, 1)),
    ("hello_signed", enc_hello(0, 1, 2, ("acme", b"opensesame", 7))),
    ("hello_ok", enc_hello_ok(0, 1, "turbo")),
    ("resolve", enc_resolve(1, "gradient")),
    ("kernel_info", enc_kernel_info(1, 3, 5, 1)),
    ("call", enc_call(2, 3, [3, 5, 2, 7, -1])),
    ("call_deadline", enc_call(20, 3, [3, 5, 2, 7, -1], 250_000)),
    ("call_batch", enc_call_batch(3, 0, 2, [[1, -2], [3, -4], [5, -6]])),
    ("call_batch_deadline", enc_call_batch(21, 0, 2, [[1, -2], [3, -4]], 1_000_000)),
    ("reply", enc_reply(3, 1, [[36], [-7], [12]])),
    ("call_batch_zero_rows", enc_call_batch(7, 2, 5, [])),
    ("error_rejected", enc_error(4, "rejected", "poly6", "acme", 7, 8)),
    ("error_unauthorized", enc_error(18, "unauthorized", "bad tenant signature")),
    ("error_version_mismatch", enc_error(0, "version_mismatch", 1, 1)),
    ("get_metrics", enc_get_metrics(9)),
    ("metrics", enc_metrics(9, '{"completed":1}')),
    ("health", enc_health(14)),
    ("health_ok", enc_health_ok(14, HEALTH_SERVING, 3)),
    ("drain", enc_drain(15)),
    ("cancel", enc_cancel(22)),
    ("error_unavailable", enc_error(16, "unavailable", "fir")),
    (
        "error_invalid_kernel",
        enc_error(17, "invalid_kernel", "poly6", "tape: dst slot 9 out of range"),
    ),
]

# Hex copies of the vectors embedded in the Rust test. Regenerate with
# --emit after an intentional (versioned!) format change.
EXPECTED_HEX = {
    "hello": "010000000000000000544d465501000100",
    "hello_signed": (
        "010000000000000000544d4655010002000400000061636d650700000000000000"
        "e81184456412c22759ad970d88d386486a8e7c8a168201be77ac6423f813aced"
    ),
    "hello_ok": "020000000000000000010005000000747572626f",
    "resolve": "030100000000000000080000006772616469656e74",
    "kernel_info": "0401000000000000000300000005000100",
    "call": "05020000000000000003000000050003000000050000000200000007000000ffffffff",
    "call_deadline": (
        "05140000000000000003000000050003000000050000000200000007000000"
        "ffffffff90d0030000000000"
    ),
    "call_batch": "0603000000000000000000000002000300000001000000feffffff03000000fcffffff05000000faffffff",
    "call_batch_deadline": (
        "0615000000000000000000000002000200000001000000feffffff03000000"
        "fcffffff40420f0000000000"
    ),
    "reply": "07030000000000000001000300000024000000f9ffffff0c000000",
    "call_batch_zero_rows": "06070000000000000002000000050000000000",
    "error_rejected": (
        "080400000000000000040005000000706f6c79360400000061636d65"
        "07000000000000000800000000000000"
    ),
    "error_unauthorized": (
        "0812000000000000006600140000006261642074656e616e74207369676e6174757265"
    ),
    "error_version_mismatch": "080000000000000000640001000100",
    "get_metrics": "090900000000000000",
    "metrics": "0a09000000000000000f0000007b22636f6d706c65746564223a317d",
    "health": "0b0e00000000000000",
    "health_ok": "0c0e000000000000000003000000",
    "drain": "0d0f00000000000000",
    "cancel": "0e1600000000000000",
    "error_unavailable": "081000000000000000090003000000666972",
    "error_invalid_kernel": (
        "0811000000000000000a0005000000706f6c79361d000000746170653a2064"
        "737420736c6f742039206f7574206f662072616e6765"
    ),
}


def frame(payload):
    """A full on-stream frame: u32 LE length prefix + payload."""
    return u32(len(payload)) + payload


def decode_smoke(payload):
    """Shallow structural decode: opcode + id + body length sanity."""
    assert len(payload) >= 9, "frame shorter than its header"
    opcode = payload[0]
    assert opcode in (
        OP_HELLO, OP_HELLO_OK, OP_RESOLVE, OP_KERNEL_INFO, OP_CALL,
        OP_CALL_BATCH, OP_REPLY, OP_ERROR, OP_GET_METRICS, OP_METRICS,
        OP_HEALTH, OP_HEALTH_OK, OP_DRAIN, OP_CANCEL,
    ), f"unknown opcode {opcode:#x}"
    (rid,) = struct.unpack_from("<Q", payload, 1)
    return opcode, rid


def hello_round_trip_property(rounds=256):
    """Random tenant Hellos survive an encode → decode round trip, and
    the one benign truncation (cutting the whole token suffix, leaving
    exactly the 17 anonymous-Hello bytes) decodes anonymous — every
    other cut inside the token is refused. Mirrors the Rust property
    `prop_signed_hellos_round_trip_and_truncate_cleanly`."""
    rng = random.Random(0x7E4A17)
    names = ["a", "acme", "tenant-7", "ütf8-ok", "x" * 40]
    for _ in range(rounds):
        tenant = rng.choice(names)
        secret = bytes(rng.randrange(256) for _ in range(rng.randrange(1, 33)))
        nonce = rng.randrange(1 << 64)
        lo, hi = 1, rng.choice([1, 2])
        body = enc_hello(3, lo, hi, (tenant, secret, nonce))
        rid, dlo, dhi, dtenant, dnonce, dmac = dec_hello(body)
        assert (rid, dlo, dhi) == (3, lo, hi)
        assert dtenant == tenant and dnonce == nonce
        assert dmac == token_mac(tenant, secret, nonce)
        # The only cut that decodes at all is the anonymous prefix.
        anon = dec_hello(body[:ANON_HELLO_LEN])
        assert anon[3:] == (None, None, None)
        cut = rng.randrange(ANON_HELLO_LEN + 1, len(body))
        try:
            dec_hello(body[:cut])
        except AssertionError:
            pass
        except (struct.error, UnicodeDecodeError, IndexError):
            pass
        else:
            raise SystemExit(
                f"truncated token accepted at cut {cut} of {len(body)}"
            )


def deadline_call_round_trip_property(rounds=256):
    """Random deadline-carrying Calls survive an encode -> decode round
    trip; cutting the frame back to its base length legally decodes as
    the deadline-free Call (the suffix is optional), while every cut
    strictly inside the 8-byte suffix is refused. Mirrors the Rust
    property `prop_deadline_calls_round_trip_and_truncate_cleanly`."""
    rng = random.Random(0x0E06)
    for _ in range(rounds):
        arity = rng.randrange(0, 9)
        inputs = [rng.randrange(-(1 << 31), 1 << 31) for _ in range(arity)]
        rid = rng.randrange(1 << 64)
        kernel = rng.randrange(1 << 32)
        deadline = rng.randrange(1 << 64)
        body = enc_call(rid, kernel, inputs, deadline)
        assert dec_call(body) == (rid, kernel, inputs, deadline)
        base = len(body) - 8
        assert dec_call(body[:base]) == (rid, kernel, inputs, None), (
            "base-length cut must decode deadline-free"
        )
        cut = rng.randrange(base + 1, len(body))
        try:
            dec_call(body[:cut])
        except (AssertionError, struct.error):
            pass
        else:
            raise SystemExit(
                f"partial deadline suffix accepted at cut {cut} of {len(body)}"
            )


def main():
    if "--emit" in sys.argv[1:]:
        for label, payload in GOLDEN:
            print(f"{label}: {payload.hex()}")
        return 0
    failures = 0
    for label, payload in GOLDEN:
        got = payload.hex()
        want = EXPECTED_HEX[label]
        if got != want:
            print(f"MISMATCH {label}:\n  mirror : {got}\n  golden : {want}")
            failures += 1
            continue
        decode_smoke(payload)
        f = frame(payload)
        (n,) = struct.unpack_from("<I", f, 0)
        assert n == len(payload)
    if failures:
        print(f"wire mirror: {failures} golden vector(s) diverged")
        return 1
    hello_round_trip_property()
    deadline_call_round_trip_property()
    print(
        f"wire mirror: all {len(GOLDEN)} golden vectors match the spec "
        "(+ tenant-hello and deadline-call round-trip properties)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
