#!/usr/bin/env python3
"""Cycle-accurate cross-check for the benchmark kernels.

Mirrors `arch::{Fu, Pipeline}` (fu.rs / dsp48e1.rs / pipeline.rs) and
verifies, for every kernel in ``benchmarks/src``:

  * simulated outputs == functional oracle on random packets;
  * first packet completes exactly at `Timing::latency()`;
  * steady-state output gaps == the analytical II
    (the `validate_against_schedule` / `measure_ii` invariants).

This is the toolchain-free stand-in for the Rust tests
`measured_ii_matches_model` and `dynamic_matches_static_for_all_benchmarks`.
"""

import os
import random
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from gen_dfg_json import (  # noqa: E402
    KERNELS,
    Parser,
    SRC_DIR,
    apply_op,
    evaluate,
    lower,
    normalize,
    schedule,
    timing,
    tokenize,
)

LATENCY = 2  # DSP delay line depth


class Fu:
    def __init__(self, instrs, consts, n_loads):
        # instrs: list of ("op", opname, rs1, rs2) | ("byp", rs)
        self.im = instrs
        self.rf = [0] * 32
        for i, c in enumerate(consts):
            self.rf[31 - i] = c
        self.n_loads = n_loads
        self.dc = 0
        self.pc = 0
        self.state = "load"
        self.flush_left = 0
        self.line = [None] * LATENCY

    def backpressure(self):
        return self.state != "load" or self.dc >= self.n_loads

    def step(self, inp):
        if self.state == "load" and self.dc >= self.n_loads:
            self.state = "exec"
            self.pc = 0
        if inp is not None:
            assert self.state == "load" and self.dc < self.n_loads, "protocol violation"
            self.rf[self.dc] = inp
            self.dc += 1
        issue = None
        if self.state == "exec":
            ins = self.im[self.pc]
            if ins[0] == "op":
                issue = apply_op(ins[1], self.rf[ins[2]], self.rf[ins[3]])
            else:
                issue = self.rf[ins[1]]
            self.pc += 1
            if self.pc == len(self.im):
                self.state = "flush"
                self.flush_left = LATENCY
        out = self.line[0]
        self.line = self.line[1:] + [issue]
        if self.state == "flush":
            if self.flush_left == 0:
                self.dc = 0
                self.state = "load"
            else:
                self.flush_left -= 1
        return out


class Pipeline:
    def __init__(self, nodes, stages, output_order, ii):
        self.fus = []
        for st in stages:
            slot = {v: i for i, v in enumerate(st["arrivals"])}
            for i, (c, _) in enumerate(st["consts"]):
                slot[c] = 31 - i
            instrs = [
                ("op", nodes[o]["op"], slot[nodes[o]["args"][0]], slot[nodes[o]["args"][1]])
                for o in st["ops"]
            ]
            instrs += [("byp", slot[b]) for b in st["bypasses"]]
            self.fus.append(Fu(instrs, [c[1] for c in st["consts"]], st["n_loads"]))
        self.n_inputs = stages[0]["n_loads"]
        self.n_out = stages[-1]["n_execs"]
        self.output_order = output_order
        self.ii = ii
        self.in_fifo = []
        self.out_fifo = []
        self.next_packet_cycle = 1
        self.packet_word = 0
        self.cycle = 0

    def enqueue(self, packet):
        if 4096 - len(self.in_fifo) < len(packet):
            return False
        self.in_fifo.extend(packet)
        return True

    def step(self):
        self.cycle += 1
        at_boundary = self.packet_word == 0
        gate_open = (not at_boundary) or self.cycle >= self.next_packet_cycle
        carry = None
        if not self.fus[0].backpressure() and gate_open and self.in_fifo:
            carry = self.in_fifo.pop(0)
            if at_boundary:
                self.next_packet_cycle = self.cycle + self.ii
            self.packet_word += 1
            if self.packet_word == self.n_inputs:
                self.packet_word = 0
        for fu in self.fus:
            carry = fu.step(carry)
        if carry is not None:
            self.out_fifo.append(carry)

    def run(self, packets, max_cycles):
        """Returns (outputs, completion_cycles)."""
        nxt, out, done_at = 0, [], []
        start = self.cycle
        while len(out) < len(packets):
            assert self.cycle - start <= max_cycles, "cycle budget exceeded"
            if nxt < len(packets) and self.enqueue(packets[nxt]):
                nxt += 1
            self.step()
            while len(self.out_fifo) >= self.n_out:
                words = [self.out_fifo.pop(0) for _ in range(self.n_out)]
                out.append([words[pos] for _, pos in self.output_order])
                done_at.append(self.cycle)
        return out, done_at


def main():
    rng = random.Random(2016)
    for name in KERNELS:
        with open(os.path.join(SRC_DIR, f"{name}.k")) as f:
            src = f.read()
        kname, params, body, returns = Parser(tokenize(src)).kernel()
        nodes = normalize(lower(kname, params, body, returns))
        stages, output_order, _ = schedule(name, nodes)
        ii, latency = timing(stages)
        n_in = stages[0]["n_loads"]
        # Oracle agreement on random packets (incl. extremes).
        packets = [[rng.randrange(-(2**31), 2**31) for _ in range(n_in)] for _ in range(8)]
        packets.append([2**31 - 1] * n_in)
        packets.append([-(2**31)] * n_in)
        pl = Pipeline(nodes, stages, output_order, ii)
        out, done_at = pl.run(packets, 100_000)
        for pkt, got in zip(packets, out):
            want = evaluate(nodes, pkt)
            assert got == want, f"{name}: {pkt} -> {got}, oracle {want}"
        # Static-vs-dynamic: first completion at `latency`, then II gaps.
        pl2 = Pipeline(nodes, stages, output_order, ii)
        sample = [[k] * n_in for k in range(10)]
        _, cycles = pl2.run(sample, 100_000)
        assert cycles[0] == latency, f"{name}: first out at {cycles[0]}, model {latency}"
        gaps = [b - a for a, b in zip(cycles, cycles[1:])]
        assert all(g == ii for g in gaps[1:]), f"{name}: gaps {gaps} vs II {ii}"
        mean_gap = sum(gaps) / len(gaps)
        assert abs(mean_gap - ii) < 1e-9, f"{name}: measured II {mean_gap} vs {ii}"
        print(f"{name:<10} oracle ok, first output @{cycles[0]:>3} (= latency), II {ii} exact")
    print("\ncycle-accurate model matches the analytical II/latency for all kernels")


if __name__ == "__main__":
    main()
