#!/usr/bin/env bash
# Failover smoke for `tmfu router` (DESIGN.md §11): two `tmfu listen`
# replicas behind one router, a burst of calls through the front, and
# a `kill -9` of one replica while the burst is running. Every call
# must still complete (the survivor absorbs the retried work), and
# both the router and the surviving backend must then drain cleanly
# on SIGTERM. Run by `make router-smoke` (part of `make verify`).
set -euo pipefail

BIN=${BIN:-target/release/tmfu}
TMP=${TMPDIR:-/tmp}
SA=$(mktemp -u "$TMP/tmfu-router-a-XXXXXX.sock")
SB=$(mktemp -u "$TMP/tmfu-router-b-XXXXXX.sock")
SR=$(mktemp -u "$TMP/tmfu-router-front-XXXXXX.sock")

cleanup() {
    for pid in "${APID:-}" "${BPID:-}" "${RPID:-}"; do
        [ -n "$pid" ] && kill -9 "$pid" 2>/dev/null || true
    done
    rm -f "$SA" "$SB" "$SR"
}
trap cleanup EXIT

wait_sock() {
    for _ in $(seq 1 200); do
        [ -S "$1" ] && return 0
        sleep 0.05
    done
    echo "router smoke: socket $1 never appeared"
    exit 1
}

# Two replicas, then the router fronting both. Short probe period so
# the death is noticed quickly; the per-call retry budget rides over
# the window where the routing table still lists the dead replica.
"$BIN" listen --socket "$SA" --tcp= --backend turbo &
APID=$!
"$BIN" listen --socket "$SB" --tcp= --backend turbo &
BPID=$!
wait_sock "$SA"
wait_sock "$SB"
"$BIN" router --backends "unix:$SA,unix:$SB" --socket "$SR" --tcp= \
    --probe-ms 100 --retries 6 --timeout-ms 30000 &
RPID=$!
wait_sock "$SR"

# The chaos: SIGKILL replica A shortly after the burst starts. Whether
# the signal lands mid-burst or just after, every call must settle —
# gradient(3,5,2,7,1) = 36, 400 times over.
(
    sleep 0.2
    kill -9 "$APID"
) &
KPID=$!
OUT=$("$BIN" call gradient --addr "unix:$SR" --inputs 3,5,2,7,1 \
    --count 400 --retries 6 --timeout-ms 30000 2>&1)
wait "$KPID"
APID=""
echo "$OUT"
echo "$OUT" | grep -qx "36" \
    || { echo "router smoke: expected result 36"; exit 1; }
echo "$OUT" | grep -q "400 calls completed" \
    || { echo "router smoke: burst did not fully complete"; exit 1; }

# Graceful drain: SIGTERM finishes in-flight work, then exit 0 — for
# the router first, then the surviving replica.
kill -TERM "$RPID"
wait "$RPID" || { echo "router smoke: router did not drain cleanly"; exit 1; }
RPID=""
kill -TERM "$BPID"
wait "$BPID" || { echo "router smoke: backend did not drain cleanly"; exit 1; }
BPID=""
echo "router smoke: OK (400-call burst over a kill -9'd replica + SIGTERM drains)"
