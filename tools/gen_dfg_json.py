#!/usr/bin/env python3
"""Offline mirror of the Rust compiler pipeline.

Re-implements, byte-for-byte, the path

    frontend (lex/parse/lower) -> dfg::normalize -> sched::Program
    -> sched::Timing -> sched::program_to_json -> Json::to_string_pretty

so that the committed ``benchmarks/dfg/*.json`` interchange files can be
(re)generated and the Table II characteristics of the ``benchmarks/src``
kernels can be cross-checked without a Rust toolchain.  The Rust test
``committed_dfg_jsons_are_in_sync`` compares these files against
``tmfu export-dfg``; when a toolchain is available, prefer regenerating
with ``target/release/tmfu export-dfg``.

Usage:  python3 tools/gen_dfg_json.py [--check-only]
"""

import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_DIR = os.path.join(ROOT, "benchmarks", "src")
OUT_DIR = os.path.join(ROOT, "benchmarks", "dfg")

KERNELS = [
    "gradient",
    "chebyshev",
    "sgfilter",
    "mibench",
    "qspline",
    "poly5",
    "poly6",
    "poly7",
    "poly8",
]

# Paper Table II rows: (in, out, edges, ops, depth, ii).
PAPER = {
    "chebyshev": (1, 1, 12, 7, 7, 6),
    "sgfilter": (2, 1, 27, 18, 9, 10),
    "mibench": (3, 1, 22, 13, 6, 11),
    "qspline": (7, 1, 50, 26, 8, 18),
    "poly5": (3, 1, 43, 27, 9, 14),
    "poly6": (3, 1, 72, 44, 11, 17),
    "poly7": (3, 1, 62, 39, 13, 17),
    "poly8": (3, 1, 51, 32, 11, 15),
}

FLUSH_CYCLES = 2
PIPE_LATENCY = 2

COMMUTATIVE = {"add", "mul", "and", "or", "xor"}


def wrap32(v):
    return ((v + (1 << 31)) & 0xFFFFFFFF) - (1 << 31)


def apply_op(op, a, b):
    if op == "add":
        return wrap32(a + b)
    if op == "sub":
        return wrap32(a - b)
    if op == "mul":
        return wrap32(a * b)
    if op == "and":
        return a & b
    if op == "or":
        return a | b
    if op == "xor":
        return a ^ b
    raise ValueError(op)


# ---------------------------------------------------------------------
# Frontend: lexer + recursive-descent parser (mirrors frontend/{lexer,
# parser}.rs for the subset the benchmark kernels use).
# ---------------------------------------------------------------------

def tokenize(src):
    toks, i, n = [], 0, len(src)
    while i < n:
        c = src[i]
        if c in " \t\r\n":
            i += 1
        elif c == "#" or src[i : i + 2] == "//":
            while i < n and src[i] != "\n":
                i += 1
        elif c in "(){},;=+-*&|^":
            toks.append(c)
            i += 1
        elif c.isdigit():
            j = i
            while j < n and (src[j].isdigit() or src[j] in "xX" or src[j] in "abcdefABCDEF"):
                j += 1
            text = src[i:j]
            toks.append(("int", int(text, 16) if text[:2].lower() == "0x" else int(text)))
            i = j
        elif c.isalpha() or c == "_":
            j = i
            while j < n and (src[j].isalnum() or src[j] == "_"):
                j += 1
            toks.append(("word", src[i:j]))
            i = j
        else:
            raise SyntaxError(f"unexpected character {c!r}")
    toks.append(("eof", None))
    return toks


class Parser:
    LEVELS = [[("|", "or")], [("^", "xor")], [("&", "and")],
              [("+", "add"), ("-", "sub")], [("*", "mul")]]

    def __init__(self, toks):
        self.toks = toks
        self.pos = 0

    def peek(self):
        return self.toks[self.pos]

    def bump(self):
        t = self.toks[self.pos]
        if self.pos < len(self.toks) - 1:
            self.pos += 1
        return t

    def expect(self, want):
        t = self.bump()
        if t != want:
            raise SyntaxError(f"expected {want!r}, found {t!r}")

    def ident(self):
        t = self.bump()
        if not (isinstance(t, tuple) and t[0] == "word"):
            raise SyntaxError(f"expected identifier, found {t!r}")
        return t[1]

    def kernel(self):
        t = self.bump()
        assert t == ("word", "kernel")
        name = self.ident()
        self.expect("(")
        params = []
        if self.peek() != ")":
            while True:
                params.append(self.ident())
                if self.peek() == ",":
                    self.bump()
                else:
                    break
        self.expect(")")
        self.expect("{")
        body, returns = [], None
        while True:
            t = self.peek()
            if t == ("word", "return"):
                self.bump()
                returns = [self.expr()]
                while self.peek() == ",":
                    self.bump()
                    returns.append(self.expr())
                self.expect(";")
                break
            name2 = self.ident()
            self.expect("=")
            e = self.expr()
            self.expect(";")
            body.append((name2, e))
        self.expect("}")
        self.expect(("eof", None))
        return name, params, body, returns

    def expr(self, level=0):
        if level == len(self.LEVELS):
            return self.unary()
        lhs = self.expr(level + 1)
        while True:
            hit = None
            for tok, op in self.LEVELS[level]:
                if self.peek() == tok:
                    hit = op
                    break
            if hit is None:
                return lhs
            self.bump()
            rhs = self.expr(level + 1)
            lhs = ("bin", hit, lhs, rhs)

    def unary(self):
        if self.peek() == "-":
            self.bump()
            return ("neg", self.unary())
        return self.atom()

    def atom(self):
        t = self.bump()
        if isinstance(t, tuple) and t[0] == "word":
            return ("var", t[1])
        if isinstance(t, tuple) and t[0] == "int":
            return ("lit", t[1])
        if t == "(":
            e = self.expr()
            self.expect(")")
            return e
        raise SyntaxError(f"expected expression, found {t!r}")


# ---------------------------------------------------------------------
# DFG: nodes are dicts mirroring dfg::Node.
#   {"kind": "input", "name": n} | {"kind": "const", "value": v}
#   {"kind": "op", "op": o, "args": [a, b]} | {"kind": "output", ...}
# ---------------------------------------------------------------------

def lower(name, params, body, returns):
    nodes, env = [], {}

    def push(node):
        nodes.append(node)
        return len(nodes) - 1

    def lower_expr(e):
        k = e[0]
        if k == "var":
            return env[e[1]]
        if k == "lit":
            return push({"kind": "const", "value": wrap32(e[1])})
        if k == "bin":
            a = lower_expr(e[2])
            b = lower_expr(e[3])
            return push({"kind": "op", "op": e[1], "args": [a, b]})
        if k == "neg":
            zero = push({"kind": "const", "value": 0})
            v = lower_expr(e[1])
            return push({"kind": "op", "op": "sub", "args": [zero, v]})
        raise ValueError(k)

    for p in params:
        env[p] = push({"kind": "input", "name": p})
    for var, e in body:
        assert var not in env, f"{name}: {var} reassigned"
        env[var] = lower_expr(e)
    multi = len(returns) > 1
    for i, r in enumerate(returns):
        v = lower_expr(r)
        push({"kind": "output", "name": f"out{i}" if multi else "out", "args": [v]})
    return nodes


def constant_fold(nodes):
    out, mapping = [], []
    for n in nodes:
        if n["kind"] == "op":
            a, b = mapping[n["args"][0]], mapping[n["args"][1]]
            na, nb = out[a], out[b]
            if na["kind"] == "const" and nb["kind"] == "const":
                out.append({"kind": "const", "value": apply_op(n["op"], na["value"], nb["value"])})
            else:
                out.append({"kind": "op", "op": n["op"], "args": [a, b]})
        elif n["kind"] == "output":
            out.append({"kind": "output", "name": n["name"], "args": [mapping[n["args"][0]]]})
        else:
            out.append(dict(n))
        mapping.append(len(out) - 1)
    return out


def cse(nodes):
    out, mapping = [], []
    seen_ops, seen_consts = {}, {}
    for n in nodes:
        if n["kind"] == "const":
            v = n["value"]
            if v in seen_consts:
                mapping.append(seen_consts[v])
                continue
            out.append(dict(n))
            seen_consts[v] = len(out) - 1
        elif n["kind"] == "op":
            a, b = mapping[n["args"][0]], mapping[n["args"][1]]
            if n["op"] in COMMUTATIVE and a > b:
                a, b = b, a
            key = (n["op"], a, b)
            if key in seen_ops:
                mapping.append(seen_ops[key])
                continue
            out.append({"kind": "op", "op": n["op"], "args": [a, b]})
            seen_ops[key] = len(out) - 1
        elif n["kind"] == "output":
            out.append({"kind": "output", "name": n["name"], "args": [mapping[n["args"][0]]]})
        else:
            out.append(dict(n))
        mapping.append(len(out) - 1)
    return out


def dce(nodes):
    live = [False] * len(nodes)

    def mark(i):
        if live[i]:
            return
        live[i] = True
        for a in nodes[i].get("args", []):
            mark(a)

    for i, n in enumerate(nodes):
        if n["kind"] == "output":
            mark(i)
        if n["kind"] == "input":
            live[i] = True
    out, mapping = [], [None] * len(nodes)
    for i, n in enumerate(nodes):
        if not live[i]:
            continue
        m = dict(n)
        if "args" in m:
            m["args"] = [mapping[a] for a in m["args"]]
        out.append(m)
        mapping[i] = len(out) - 1
    return out


def normalize(nodes):
    cur = nodes
    for _ in range(16):
        nxt = dce(cse(constant_fold(cur)))
        if nxt == cur:
            return nxt
        cur = nxt
    return cur


def evaluate(nodes, inputs):
    vals, outs, next_in = [0] * len(nodes), [], 0
    for i, n in enumerate(nodes):
        if n["kind"] == "input":
            vals[i] = inputs[next_in]
            next_in += 1
        elif n["kind"] == "const":
            vals[i] = n["value"]
        elif n["kind"] == "op":
            vals[i] = apply_op(n["op"], vals[n["args"][0]], vals[n["args"][1]])
        else:
            vals[i] = vals[n["args"][0]]
            outs.append(vals[i])
    return outs


# ---------------------------------------------------------------------
# Scheduler mirror: Levels, Routing, Program stages, Timing.
# ---------------------------------------------------------------------

def levels_of(nodes):
    level, depth = [0] * len(nodes), 0
    for i, n in enumerate(nodes):
        if n["kind"] == "op":
            level[i] = 1 + max(level[a] for a in n["args"])
            depth = max(depth, level[i])
        elif n["kind"] == "output":
            level[i] = level[n["args"][0]]
    return level, depth


def routing_of(nodes, level, depth):
    routes = {}  # id -> [producer, consumer_stages, last_stage]
    for i, n in enumerate(nodes):
        if n["kind"] == "input":
            routes[i] = [0, [], 0]
        elif n["kind"] == "op":
            routes[i] = [level[i], [], 0]
    for i, n in enumerate(nodes):
        if n["kind"] == "op":
            for a in n["args"]:
                if a in routes:
                    routes[a][1].append(level[i])
        elif n["kind"] == "output":
            routes[n["args"][0]][1].append(depth + 1)
    for r in routes.values():
        r[1] = sorted(set(r[1]))
        r[2] = r[1][-1] if r[1] else r[0]
    for r in routes.values():
        if not r[1] and r[0] == 0:
            r[2] = 1
    return routes


def bypass_stages(route):
    return range(route[0] + 1, route[2])


def schedule(name, nodes):
    level, depth = levels_of(nodes)
    assert depth > 0, f"{name}: no operations"
    routes = routing_of(nodes, level, depth)
    input_ids = [i for i, n in enumerate(nodes) if n["kind"] == "input"]
    stages = []
    for s in range(1, depth + 1):
        ops = [i for i, n in enumerate(nodes) if n["kind"] == "op" and level[i] == s]
        if s == 1:
            arrivals = list(input_ids)
        else:
            arrivals = [
                i
                for i, n in enumerate(nodes)
                if n["kind"] == "op" and level[i] == s - 1 and routes[i][2] >= s
            ]
            arrivals += [i for i in sorted(routes) if (s - 1) in bypass_stages(routes[i])]
        byps = [i for i in sorted(routes) if s in bypass_stages(routes[i])]
        consts = []
        for op in ops:
            for a in nodes[op]["args"]:
                if nodes[a]["kind"] == "const" and all(c[0] != a for c in consts):
                    consts.append((a, nodes[a]["value"]))
        assert len(arrivals) + len(consts) <= 32, f"{name} stage {s}: RF overflow"
        n_execs = len(ops) + len(byps)
        assert n_execs <= 32, f"{name} stage {s}: IM overflow"
        stages.append(
            {
                "stage": s,
                "ops": ops,
                "arrivals": arrivals,
                "bypasses": byps,
                "consts": consts,
                "n_loads": len(arrivals),
                "n_execs": n_execs,
            }
        )
    # check_dataflow: each stage's arrivals == previous stage's emissions.
    for prev, cur in zip(stages, stages[1:]):
        emitted = prev["ops"] + prev["bypasses"]
        assert len(emitted) == len(cur["arrivals"]), f"{name}: dataflow width mismatch"
        it = iter(emitted)
        for want in cur["arrivals"]:
            assert any(got == want for got in it), f"{name}: arrival {want} out of order"
    last = stages[-1]
    emissions = last["ops"] + last["bypasses"]
    output_order = []
    for i, n in enumerate(nodes):
        if n["kind"] == "output":
            output_order.append((n["name"], emissions.index(n["args"][0])))
    return stages, output_order, depth


def timing(stages):
    ii = max(st["n_loads"] + st["n_execs"] for st in stages) + FLUSH_CYCLES
    t = 1
    for st in stages:
        t += st["n_loads"] + PIPE_LATENCY
    first_output = t
    latency = first_output + stages[-1]["n_execs"] - 1
    return ii, latency


# ---------------------------------------------------------------------
# JSON emitter mirroring util::json (sorted object keys, 2-space pretty).
# ---------------------------------------------------------------------

def emit(v, level=0):
    pad, pad1 = "  " * level, "  " * (level + 1)
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, int):
        return str(v)
    if isinstance(v, str):
        out = ['"']
        for c in v:
            if c == '"':
                out.append('\\"')
            elif c == "\\":
                out.append("\\\\")
            elif c == "\n":
                out.append("\\n")
            elif ord(c) < 0x20:
                out.append(f"\\u{ord(c):04x}")
            else:
                out.append(c)
        out.append('"')
        return "".join(out)
    if isinstance(v, list):
        if not v:
            return "[]"
        inner = (",\n" + pad1).join(emit(x, level + 1) for x in v)
        return "[\n" + pad1 + inner + "\n" + pad + "]"
    if isinstance(v, dict):
        if not v:
            return "{}"
        items = sorted(v.items())
        inner = (",\n" + pad1).join(f"{emit(k)}: {emit(x, level + 1)}" for k, x in items)
        return "{\n" + pad1 + inner + "\n" + pad + "}"
    raise TypeError(type(v))


def program_json(name, nodes, stages, output_order, ii, latency):
    jnodes = []
    for n in nodes:
        if n["kind"] == "input":
            jnodes.append({"kind": "input", "name": n["name"]})
        elif n["kind"] == "const":
            jnodes.append({"kind": "const", "value": n["value"]})
        elif n["kind"] == "op":
            jnodes.append({"kind": "op", "op": n["op"], "args": list(n["args"])})
        else:
            jnodes.append({"kind": "output", "name": n["name"], "args": list(n["args"])})
    jstages = [
        {
            "stage": st["stage"],
            "ops": list(st["ops"]),
            "arrivals": list(st["arrivals"]),
            "bypasses": list(st["bypasses"]),
            "consts": [{"node": c[0], "value": c[1]} for c in st["consts"]],
            "n_loads": st["n_loads"],
            "n_execs": st["n_execs"],
        }
        for st in stages
    ]
    return {
        "dfg": {"name": name, "nodes": jnodes},
        "schedule": {
            "n_stages": len(stages),
            "ii": ii,
            "latency": latency,
            "stages": jstages,
            "output_order": [{"name": n, "pos": p} for n, p in output_order],
        },
    }


def characteristics(nodes):
    level, depth = levels_of(nodes)
    n_in = sum(1 for n in nodes if n["kind"] == "input")
    n_out = sum(1 for n in nodes if n["kind"] == "output")
    n_ops = sum(1 for n in nodes if n["kind"] == "op")
    edges = 0
    for n in nodes:
        if n["kind"] == "op":
            edges += sum(1 for a in n["args"] if nodes[a]["kind"] != "const")
        elif n["kind"] == "output":
            edges += 1
    return n_in, n_out, edges, n_ops, depth


def main():
    check_only = "--check-only" in sys.argv
    failures = []
    for name in KERNELS:
        with open(os.path.join(SRC_DIR, f"{name}.k")) as f:
            src = f.read()
        kname, params, body, returns = Parser(tokenize(src)).kernel()
        assert kname == name, f"{name}: kernel named {kname}"
        nodes = normalize(lower(kname, params, body, returns))
        assert normalize(nodes) == nodes, f"{name}: normalize not idempotent"
        n_in, n_out, edges, n_ops, depth = characteristics(nodes)
        stages, output_order, _ = schedule(name, nodes)
        ii, latency = timing(stages)
        n_instr = sum(st["n_execs"] for st in stages)
        print(
            f"{name:<10} io {n_in}/{n_out}  edges {edges:>3}  ops {n_ops:>3}  "
            f"depth {depth:>2}  II {ii:>2}  latency {latency:>3}  ctx {n_instr * 5} B"
        )
        if name in PAPER:
            pin, pout, pedges, pops, pdepth, pii = PAPER[name]
            for label, got, want, exact in [
                ("io_in", n_in, pin, True),
                ("io_out", n_out, pout, True),
                ("ops", n_ops, pops, True),
                ("depth", depth, pdepth, True),
                ("ii", ii, pii, True),
                ("edges", edges, pedges, False),
            ]:
                if exact and got != want:
                    failures.append(f"{name}: {label} {got} != paper {want}")
                if not exact and abs(got - want) / want > 0.10:
                    failures.append(f"{name}: {label} {got} vs paper {want} (>10%)")
        if name == "gradient":
            assert evaluate(nodes, [3, 5, 2, 7, 1]) == [36]
            assert ii == 11 and depth == 4 and n_ops == 11
            assert stages[0]["n_loads"] == 5
            assert latency == 24, latency
        if name == "chebyshev":
            assert evaluate(nodes, [2]) == [362]
            assert n_instr == 13
        text = emit(program_json(name, nodes, stages, output_order, ii, latency))
        path = os.path.join(OUT_DIR, f"{name}.json")
        if check_only:
            with open(path) as f:
                if f.read() != text:
                    failures.append(f"{name}: committed JSON is stale")
        else:
            with open(path, "w") as f:
                f.write(text)
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(f"  {f}")
        sys.exit(1)
    print("\nall kernels match the paper's Table II characteristics")


if __name__ == "__main__":
    main()
