#!/usr/bin/env python3
"""Offline replica of `rust/tests/integration.rs::fuzz_full_chain_against_oracle`.

Reproduces the exact xoshiro256** stream (`util::prng::Rng`) and the
random kernel generator, then drives every generated kernel through the
compiler mirror and the cycle-accurate pipeline mirrors (single-bank
`Fu` and double-buffered `FuDb`), asserting the same invariants the
Rust test asserts:

  * outputs match the functional oracle (both pipeline variants);
  * measured steady-state II == the analytical model, exactly;
  * scheduling failures only ever report RF/IM overflow;
  * at least 40 of the 60 cases are exercised.

Run before shipping compiler/scheduler changes when no Rust toolchain
is available.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from gen_dfg_json import (  # noqa: E402
    KERNELS,
    Parser,
    SRC_DIR,
    evaluate,
    lower,
    normalize,
    schedule,
    timing,
    tokenize,
)
from sim_check import Fu, Pipeline  # noqa: E402

M64 = (1 << 64) - 1


class SplitMix64:
    def __init__(self, seed):
        self.state = seed & M64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng:
    """Bit-exact mirror of util::prng::Rng (xoshiro256**)."""

    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def below(self, bound):
        assert bound > 0
        while True:
            x = self.next_u64()
            w = x * bound
            hi, lo = w >> 64, w & M64
            if lo >= bound or lo >= ((-x) & M64) % bound:
                return hi

    def index(self, bound):
        return self.below(bound)

    def range_i64(self, lo, hi):
        span = hi - lo + 1
        v = lo + self.below(span)
        # wrapping add in i64 space (never wraps for our ranges)
        return v

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def chance(self, p):
        return self.f64() < p

    def choose(self, xs):
        return xs[self.index(len(xs))]


def random_kernel_source(rng, case_id):
    n_in = 1 + rng.index(6)
    n_stmts = 3 + rng.index(24)
    params = [f"x{i}" for i in range(n_in)]
    variables = list(params)
    ops = ["+", "-", "*", "&", "|", "^"]
    body = []
    for s in range(n_stmts):
        name = f"t{s}"
        a = rng.choose(variables)
        op_space = 3 if rng.chance(0.7) else 6
        op = ops[rng.index(op_space)]
        if rng.chance(0.3):
            rhs = str(rng.range_i64(-64, 64))
        else:
            rhs = rng.choose(variables)
        body.append(f"  {name} = {a} {op} {rhs};\n")
        variables.append(name)
    ret = variables[-1]
    return "kernel rand{}({}) {{\n{}  return {};\n}}".format(
        case_id, ", ".join(params), "".join(body), ret
    )


# --- double-buffered FU / pipeline mirror (arch::{fu_db, pipeline_db}) ---


class FuDb:
    def __init__(self, instrs, consts, n_loads):
        from gen_dfg_json import apply_op

        self.apply_op = apply_op
        self.im = instrs
        bank = [0] * 32
        for i, c in enumerate(consts):
            bank[31 - i] = c
        self.banks = [list(bank), list(bank)]
        self.write_bank = 0
        self.n_loads = n_loads
        self.dc = 0
        self.pc = None
        self.pending_swap = False
        self.line = [None, None]

    def can_accept(self):
        return self.dc < self.n_loads

    def _maybe_swap(self):
        if self.pc is None and self.pending_swap:
            self.write_bank ^= 1
            self.pending_swap = False
            self.dc = 0
            self.pc = 0

    def step(self, inp):
        self._maybe_swap()
        if inp is not None:
            assert self.dc < self.n_loads, "write bank overrun"
            self.banks[self.write_bank][self.dc] = inp
            self.dc += 1
            if self.dc == self.n_loads:
                self.pending_swap = True
        self._maybe_swap()
        issue = None
        if self.pc is not None:
            ins = self.im[self.pc]
            bank = self.banks[self.write_bank ^ 1]
            if ins[0] == "op":
                issue = self.apply_op(ins[1], bank[ins[2]], bank[ins[3]])
            else:
                issue = bank[ins[1]]
            self.pc = None if self.pc + 1 == len(self.im) else self.pc + 1
        out = self.line[0]
        self.line = [self.line[1], issue]
        return out


class PipelineDb:
    def __init__(self, nodes, stages, output_order):
        self.fus = []
        for st in stages:
            slot = {v: i for i, v in enumerate(st["arrivals"])}
            for i, (c, _) in enumerate(st["consts"]):
                slot[c] = 31 - i
            instrs = [
                ("op", nodes[o]["op"], slot[nodes[o]["args"][0]], slot[nodes[o]["args"][1]])
                for o in st["ops"]
            ]
            instrs += [("byp", slot[b]) for b in st["bypasses"]]
            self.fus.append(FuDb(instrs, [c[1] for c in st["consts"]], st["n_loads"]))
        self.n_inputs = stages[0]["n_loads"]
        self.n_out = stages[-1]["n_execs"]
        self.output_order = output_order
        self.ii = max(max(st["n_loads"], st["n_execs"]) for st in stages) or 1
        self.in_fifo = []
        self.out_fifo = []
        self.next_packet_cycle = 1
        self.words_in = 0
        self.cycle = 0

    def enqueue(self, packet):
        if 4096 - len(self.in_fifo) < len(packet):
            return False
        self.in_fifo.extend(packet)
        return True

    def step(self):
        self.cycle += 1
        at_boundary = self.words_in % self.n_inputs == 0
        gate_open = (not at_boundary) or self.cycle >= self.next_packet_cycle
        carry = None
        if self.fus[0].can_accept() and gate_open and self.in_fifo:
            carry = self.in_fifo.pop(0)
            if at_boundary:
                self.next_packet_cycle = self.cycle + self.ii
            self.words_in += 1
        for fu in self.fus:
            carry = fu.step(carry)
        if carry is not None:
            self.out_fifo.append(carry)

    def run(self, packets, max_cycles):
        nxt, out = 0, []
        start = self.cycle
        while len(out) < len(packets):
            assert self.cycle - start <= max_cycles, "db cycle budget exceeded"
            if nxt < len(packets) and self.enqueue(packets[nxt]):
                nxt += 1
            self.step()
            while len(self.out_fifo) >= self.n_out:
                words = [self.out_fifo.pop(0) for _ in range(self.n_out)]
                out.append([words[pos] for _, pos in self.output_order])
        return out


def measure_ii(pl, sample):
    assert len(sample) >= 4
    nxt, seen, completions = 0, 0, []
    budget = 1000 + len(sample) * 200
    start = pl.cycle
    while len(completions) < len(sample):
        assert pl.cycle - start <= budget, "II measurement did not converge"
        if nxt < len(sample) and pl.enqueue(sample[nxt]):
            nxt += 1
        pl.step()
        while len(pl.out_fifo) // pl.n_out > seen:
            seen += 1
            completions.append(pl.cycle)
    gaps = [b - a for a, b in zip(completions, completions[1:])]
    return sum(gaps) / len(gaps)


def build_single(nodes, stages, output_order, ii):
    return Pipeline(nodes, stages, output_order, ii)


def main():
    rng = Rng(0xF00D)
    tested = 0
    for case in range(60):
        src = random_kernel_source(rng, case)
        kname, params, body, returns = Parser(tokenize(src)).kernel()
        nodes = normalize(lower(kname, params, body, returns))
        n_ops = sum(1 for n in nodes if n["kind"] == "op")
        if n_ops == 0:
            continue
        try:
            stages, output_order, _ = schedule(kname, nodes)
        except AssertionError as e:
            assert "overflow" in str(e), f"unexpected scheduling failure: {e}\n{src}"
            continue
        ii, latency = timing(stages)
        n_in = sum(1 for n in nodes if n["kind"] == "input")
        packets = [
            [rng.range_i64(-10_000, 10_000) for _ in range(n_in)] for _ in range(5)
        ]
        want = [evaluate(nodes, p) for p in packets]
        pl = build_single(nodes, stages, output_order, ii)
        got, _ = pl.run(packets, 100_000)
        assert got == want, f"single-bank diverged on case {case}\n{src}"
        pldb = PipelineDb(nodes, stages, output_order)
        got_db = pldb.run(packets, 100_000)
        assert got_db == want, f"double-buffered diverged on case {case}\n{src}"
        pl2 = build_single(nodes, stages, output_order, ii)
        sample = [[k] * n_in for k in range(8)]
        measured = measure_ii(pl2, sample)
        assert abs(measured - ii) < 1e-9, f"case {case}: II {measured} vs {ii}\n{src}"
        tested += 1
    assert tested >= 40, f"only {tested} cases exercised"
    print(f"fuzz mirror: {tested}/60 cases pass (oracle, double-buffered, measured II)")

    # Benchmark kernels through the double-buffered pipeline too
    # (mirrors arch::pipeline_db::matches_oracle_on_all_benchmarks).
    for name in KERNELS:
        with open(os.path.join(SRC_DIR, f"{name}.k")) as f:
            src = f.read()
        kname, params, body, returns = Parser(tokenize(src)).kernel()
        nodes = normalize(lower(kname, params, body, returns))
        stages, output_order, _ = schedule(name, nodes)
        n_in = stages[0]["n_loads"]
        packets = [[(k * 31 + i) - 17 for i in range(n_in)] for k in range(4)]
        pldb = PipelineDb(nodes, stages, output_order)
        got = pldb.run(packets, 100_000)
        want = [evaluate(nodes, p) for p in packets]
        assert got == want, f"{name}: double-buffered diverged"
    print("double-buffered pipeline matches the oracle on all benchmark kernels")


if __name__ == "__main__":
    main()
