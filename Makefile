# Build / verification entry points. `make verify` is the CI gate.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test verify fmt clippy doc lint kernel-verify wire-smoke router-smoke bench bench-smoke bench-all bench-mirror artifacts dfg check-dfg clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --all --check

clippy:
	$(CARGO) clippy --release --all-targets -- -D warnings

# Rustdoc is part of the contract: broken intra-doc links or malformed
# examples in service/, wire/ and client/ fail the build.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps

# Loopback smoke: `tmfu listen` on a unix socket + `tmfu call`
# asserting the kernel result and a wire metrics fetch.
wire-smoke: build
	./tools/wire_smoke.sh

# Failover smoke: two `tmfu listen` replicas behind `tmfu router`, a
# 400-call burst with one replica kill -9'd while it runs, then
# SIGTERM drains of the router and the survivor (DESIGN.md §11).
router-smoke: build
	./tools/router_smoke.sh

# Textual lint gates for the concurrent runtime (DESIGN.md §12):
# un-annotated Ordering::Relaxed, poison-cascading .lock().unwrap(),
# and bare `as` casts in the wire codec. Toolchain-free.
lint:
	$(PYTHON) tools/source_lint.py

# Static verifier gate (DESIGN.md §12): every compiled kernel's DFG /
# schedule / tape / context invariants, plus the committed
# benchmarks/dfg artifacts re-validated against a fresh compile.
kernel-verify: build
	./target/release/tmfu verify --artifacts-dir benchmarks/dfg

# The full gate: formatting, lints (rustc + textual), release build,
# test suite, static kernel verifier, doc build, wire loopback smoke,
# router failover smoke, serving-perf smoke (allocation-free submit
# path AND worker loop + reactor thread ceiling + wire/router overhead
# regression).
verify: fmt clippy lint build test kernel-verify doc wire-smoke router-smoke bench-smoke

# Perf trajectory: run the serving-path benchmarks and (re)write the
# checked-in baseline JSON (packets/s per backend per kernel, sim
# cycles/s, SIMD-turbo-vs-ref headline ratio, in-flight scaling, the
# zero-allocation submit AND worker-loop audits + the wire and router
# per-call overheads, the tenant-fairness p99, and the deadline-shed /
# cancel-reclaim pair). Cargo runs bench binaries with cwd = the
# package root (rust/), hence the ../ on the path.
bench:
	$(CARGO) bench --bench bench_perf -- --json ../BENCH_PR10.json

# Fast serving-perf gate for `make verify`/CI: run bench_perf in fast
# mode and assert the hard invariants — submit_allocs_per_call == 0,
# worker_allocs_per_batch == 0, the reactor thread ceiling, the raised
# turbo floor, the router forwarding overhead staying within 3x of
# the wire framing overhead, the fair-tenant p99 bound with zero
# fair-tenant rejections, the overload-shed p99 bound against the
# no-shed backlog wait with the cancel-reclaim ceiling, and (when the
# committed baseline carries a measured number) that the wire per-call
# overhead did not regress. bench_perf itself hard-asserts the alloc
# audits; the checker re-asserts from the JSON so a silent bench edit
# cannot un-gate them.
bench-smoke: build
	TMFU_BENCH_FAST=1 $(CARGO) bench --bench bench_perf -- --json ../BENCH_SMOKE.json
	$(PYTHON) tools/bench_smoke_check.py BENCH_SMOKE.json BENCH_PR10.json

# Every bench target (paper tables/figures + perf).
bench-all:
	$(CARGO) bench

# Toolchain-free stand-in: cross-check the tape lowering against the
# Python oracle and regenerate BENCH_PR2.json from the mirror
# interpreters (clearly labeled as such in the JSON's meta.harness).
bench-mirror:
	$(PYTHON) tools/turbo_check.py --json BENCH_PR2.json

# AOT-compile the kernel artifacts for the PJRT backend (needs jax).
# The interpreter (`--backend ref`) and cycle-accurate simulator
# (`--backend sim`) backends serve without any artifacts.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts

# Regenerate the committed DFG/schedule interchange JSONs from the
# kernel sources (prefer `tmfu export-dfg` when a build exists).
dfg:
	$(CARGO) run --release --bin tmfu -- export-dfg --out-dir benchmarks/dfg

# Toolchain-free cross-check of benchmarks/dfg against the compiler
# mirror (also validates the paper's Table II characteristics).
check-dfg:
	$(PYTHON) tools/gen_dfg_json.py --check-only

clean:
	$(CARGO) clean
