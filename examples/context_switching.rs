//! Rapid hardware-task switching (the paper's headline §V result):
//! cycle through all nine kernel contexts on one overlay pipeline,
//! clocking each 40-bit context stream through the daisy-chained
//! config port, and compare the measured switch times against the
//! SCFU-SCN and partial-reconfiguration baselines.
//!
//! ```sh
//! cargo run --release --example context_switching
//! ```

use tmfu_overlay::arch::{config_port, Pipeline};
use tmfu_overlay::baseline::{hls, scfu};
use tmfu_overlay::bench_suite;
use tmfu_overlay::dfg::eval;
use tmfu_overlay::resources::SYSTEM_CLOCK_MHZ;
use tmfu_overlay::sched::Program;
use tmfu_overlay::util::table::Table;

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&format!(
        "Hardware context switching at {SYSTEM_CLOCK_MHZ} MHz"
    ))
    .header(&["kernel", "FUs", "ctx words", "bytes", "switch us", "verified"]);
    let mut total_us = 0.0;
    let mut worst = 0.0f64;
    for name in bench_suite::all_names() {
        let g = bench_suite::load(name)?;
        let p = Program::schedule(&g)?;
        // Build the context image and clock it through the config port
        // (one 40-bit word per cycle, tag-matched per FU).
        let img = p.context_image()?;
        let loaded = config_port::load_image(&img)?;
        let us = config_port::switch_time_us(&loaded, SYSTEM_CLOCK_MHZ);
        total_us += us;
        worst = worst.max(us);
        // After the switch, run a packet to prove the context works.
        let mut pl = Pipeline::new(&p, 128)?;
        let pkt: Vec<i32> = (1..=g.inputs().len() as i32).collect();
        let out = pl.run(&[pkt.clone()], 20_000)?;
        let ok = out[0] == eval(&g, &pkt);
        table.row(&[
            name.to_string(),
            p.n_fus().to_string(),
            loaded.cycles.to_string(),
            img.size_bytes_total().map_err(|e| anyhow::anyhow!("{e}"))?.to_string(),
            format!("{us:.3}"),
            if ok { "ok".into() } else { "FAIL".to_string() },
        ]);
        assert!(ok, "{name}: wrong result after context switch");
    }
    print!("{}", table.render());
    println!(
        "\nfull 9-kernel context rotation: {total_us:.2} us total, worst single switch {worst:.3} us"
    );
    println!(
        "baselines: SCFU-SCN external-memory config = {:.1} us/switch; \
         HLS partial reconfiguration = {:.0} us/switch",
        scfu::context_switch_us(scfu::WORST_CASE_CONFIG_BYTES),
        hls::context_switch_us(hls::PR_BITSTREAM_BYTES),
    );
    println!(
        "=> the overlay swaps kernels {:.0}x faster than SCFU-SCN and {:.0}x faster than PR",
        scfu::context_switch_us(scfu::WORST_CASE_CONFIG_BYTES) / worst,
        hls::context_switch_us(hls::PR_BITSTREAM_BYTES) / worst
    );
    Ok(())
}
