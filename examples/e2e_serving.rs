//! End-to-end system driver (the repo's headline validation run —
//! recorded in EXPERIMENTS.md §E2E).
//!
//! All layers compose on a real workload:
//!   L1/L2 — an execution backend: the cycle-accurate overlay
//!           simulator (default, zero setup), the DFG interpreter, the
//!           tape-compiled turbo executor, or the AOT-compiled
//!           JAX+Pallas kernels over PJRT (`make artifacts`);
//!   L3    — the typed service API: `OverlayService` fabric workers
//!           behind `Clone + Send` `KernelHandle` sessions with
//!           pre-resolved kernel ids, bounded admission queues and
//!           non-blocking `submit -> Pending` replies;
//!   L4    — (wire mode) the length-prefixed wire protocol: the same
//!           workload crosses a loopback Unix socket through a
//!           `WireServer` + `OverlayClient`, exercising framing,
//!           request-id correlation and the `RemoteKernel` mirror.
//!
//! The workload is a Poisson-arrival stream of requests over a Zipf-ish
//! kernel mix (a few hot kernels, a long tail — the multi-kernel
//! application scenario the paper's introduction motivates). Every
//! response is verified against the functional oracle; the report
//! includes wall-clock latency percentiles, throughput, context-switch
//! counts and the simulated 300 MHz fabric timeline.
//!
//! ```sh
//! cargo run --release --example e2e_serving [requests] [pipelines] \
//!     [ref|sim|pjrt|turbo] [inproc|wire]
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};
use tmfu_overlay::client::{OverlayClient, RemoteKernel, RemotePending};
use tmfu_overlay::dfg::eval;
use tmfu_overlay::exec::BackendKind;
use tmfu_overlay::service::{KernelHandle, OverlayService, Pending};
use tmfu_overlay::util::prng::Rng;
use tmfu_overlay::util::stats::Samples;
use tmfu_overlay::wire::server::WireServer;
use tmfu_overlay::wire::ListenAddr;

/// One kernel session, in-process or across the loopback socket — the
/// workload below is identical either way.
enum Session {
    Local(KernelHandle),
    Remote(RemoteKernel),
}

enum Reply {
    Local(Pending),
    Remote(RemotePending),
}

impl Session {
    fn submit(&self, inputs: &[i32]) -> anyhow::Result<Reply> {
        Ok(match self {
            Session::Local(h) => Reply::Local(h.submit(inputs)?),
            Session::Remote(r) => Reply::Remote(r.submit(inputs)?),
        })
    }
}

impl Reply {
    fn wait(self) -> anyhow::Result<Vec<i32>> {
        Ok(match self {
            Reply::Local(p) => p.wait()?,
            Reply::Remote(p) => p.wait()?,
        })
    }
}

fn main() -> anyhow::Result<()> {
    let requests: usize = std::env::args()
        .nth(1)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2000);
    let pipelines: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(2);
    let backend: BackendKind = std::env::args()
        .nth(3)
        .map(|s| s.parse())
        .transpose()
        .map_err(|e: String| anyhow::anyhow!(e))?
        .unwrap_or(BackendKind::Sim);
    let mode = std::env::args().nth(4).unwrap_or_else(|| "inproc".to_string());
    anyhow::ensure!(
        mode == "inproc" || mode == "wire",
        "mode must be 'inproc' or 'wire', got '{mode}'"
    );
    let mean_rate_per_s = 20_000.0; // Poisson arrival rate
    let max_batch = 32;

    println!("starting {pipelines} '{backend}' fabric worker(s) ({mode} mode)...");
    let service = Arc::new(
        OverlayService::builder()
            .backend(backend)
            .pipelines(pipelines)
            .max_batch(max_batch)
            .queue_depth(requests.max(1024)) // closed-loop check: admit all
            .build()?,
    );

    // One pre-resolved session handle per kernel — names are interned
    // exactly once, before the clock starts. The handles also carry
    // the compiled DFG used as the functional oracle in both modes.
    let handles = service.handles();

    // Wire mode: the same service, reached through a loopback Unix
    // socket — framing + correlation overhead included in the numbers.
    let (server, client) = if mode == "wire" {
        let path = std::env::temp_dir().join(format!("tmfu-e2e-{}.sock", std::process::id()));
        let server = WireServer::bind(Arc::clone(&service), &ListenAddr::Unix(path.clone()))?;
        let client = OverlayClient::connect(&format!("unix:{}", path.display()))?;
        println!("wire transport up on unix:{}", path.display());
        (Some(server), Some(client))
    } else {
        (None, None)
    };
    let sessions: Vec<Session> = match &client {
        None => handles.iter().cloned().map(Session::Local).collect(),
        Some(c) => handles
            .iter()
            .map(|h| Ok(Session::Remote(c.kernel(h.name())?)))
            .collect::<anyhow::Result<_>>()?,
    };

    // Zipf-ish kernel popularity: gradient & chebyshev hot, tail cold.
    let weights: Vec<f64> = (0..sessions.len()).map(|i| 1.0 / (i + 1) as f64).collect();
    let wsum: f64 = weights.iter().sum();

    let mut rng = Rng::new(2016);
    let started = Instant::now();
    let mut next_arrival = 0.0f64;

    // Collector thread: receives completions as they happen so the
    // client-side latency is not skewed by collection order. Replies
    // are Send in both modes — they cross threads like any value.
    type Job = (Reply, Vec<i32>, Instant);
    let (jobs_tx, jobs_rx) = std::sync::mpsc::channel::<Job>();
    let collector = std::thread::spawn(move || -> anyhow::Result<(Samples, usize)> {
        let mut lat = Samples::new();
        let mut wrong = 0usize;
        for (pending, want, t0) in jobs_rx {
            let got = pending.wait()?;
            lat.push(t0.elapsed().as_secs_f64() * 1e6);
            if got != want {
                wrong += 1;
            }
        }
        Ok((lat, wrong))
    });

    println!("submitting {requests} Poisson requests at ~{mean_rate_per_s:.0}/s...");
    for _ in 0..requests {
        // Poisson arrivals: sleep to the next arrival time.
        next_arrival += rng.exp(mean_rate_per_s);
        let target = started + Duration::from_secs_f64(next_arrival);
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        // Pick a kernel by popularity.
        let mut pick = rng.f64() * wsum;
        let mut idx = 0;
        for (i, w) in weights.iter().enumerate() {
            if pick < *w {
                idx = i;
                break;
            }
            pick -= w;
        }
        let handle = &handles[idx];
        let inputs: Vec<i32> = (0..handle.arity())
            .map(|_| rng.range_i64(-30_000, 30_000) as i32)
            .collect();
        let want = eval(&handle.compiled().dfg, &inputs);
        let t0 = Instant::now();
        let pending = sessions[idx].submit(&inputs)?;
        jobs_tx
            .send((pending, want, t0))
            .map_err(|_| anyhow::anyhow!("collector exited early"))?;
    }
    drop(jobs_tx);
    let (mut lat, wrong) = collector.join().expect("collector panicked")?;
    let wall = started.elapsed();

    println!("\n=== e2e serving report ({mode}) ===");
    println!(
        "requests: {requests} in {:.3}s -> {:.0} req/s sustained",
        wall.as_secs_f64(),
        requests as f64 / wall.as_secs_f64()
    );
    println!("end-to-end latency: {}", lat.summary("us"));
    if let Some(c) = &client {
        // The snapshot crosses the socket too in wire mode.
        println!("metrics fetched over the wire:");
        println!("{}", c.metrics()?.to_string_pretty());
    }
    println!("{}", service.metrics().render());
    drop(sessions);
    drop(client);
    if let Some(s) = server {
        s.shutdown();
    }
    service.shutdown()?;
    anyhow::ensure!(wrong == 0, "{wrong} responses failed verification");
    println!("verification: all {requests} responses match the functional oracle ({mode})");
    Ok(())
}
