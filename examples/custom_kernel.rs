//! Map a *user-defined* kernel onto the overlay: a 5-tap FIR-like
//! filter and a Horner polynomial, end to end through the compiler,
//! the II/area models and the cycle-accurate simulator — demonstrating
//! the overlay is a general target, not hard-wired to the paper's
//! benchmark suite.
//!
//! ```sh
//! cargo run --release --example custom_kernel [path/to/kernel.k]
//! ```

use tmfu_overlay::arch::Pipeline;
use tmfu_overlay::baseline::{hls, scfu};
use tmfu_overlay::dfg::{eval, Characteristics};
use tmfu_overlay::frontend;
use tmfu_overlay::resources::{self, ZYNQ_Z7020};
use tmfu_overlay::sched::{Program, Timing};
use tmfu_overlay::util::prng::Rng;

const FIR5: &str = r#"
    # y[n] = 3 x0 + 7 x1 + 11 x2 + 7 x3 + 3 x4 (symmetric 5-tap FIR)
    kernel fir5(x0, x1, x2, x3, x4) {
        a0 = x0 + x4;       # exploit symmetry
        a1 = x1 + x3;
        m0 = a0 * 3;
        m1 = a1 * 7;
        m2 = x2 * 11;
        s0 = m0 + m1;
        return s0 + m2;
    }
"#;

const HORNER: &str = r#"
    # p(x) = ((((x + 9) x + 28) x + 35) x + 12)  via Horner's rule
    kernel horner(x) {
        h1 = x + 9;
        h2 = h1 * x;
        h3 = h2 + 28;
        h4 = h3 * x;
        h5 = h4 + 35;
        h6 = h5 * x;
        return h6 + 12;
    }
"#;

fn analyze(src: &str) -> anyhow::Result<()> {
    let g = frontend::compile(src).map_err(|e| anyhow::anyhow!("{e}"))?;
    let c = Characteristics::of(&g);
    let p = Program::schedule(&g)?;
    let t = Timing::of(&p);
    let dev = &ZYNQ_Z7020;
    println!("== kernel '{}' ==", g.name);
    println!(
        "  DFG: {} in/{} out, {} ops, depth {}, parallelism {:.2}",
        c.n_inputs, c.n_outputs, c.n_ops, c.depth, c.avg_parallelism
    );
    println!(
        "  overlay: {} FUs, II {}, eOPC {:.2}, {:.2} GOPS @300 MHz, {} e-Slices",
        p.n_fus(),
        t.ii,
        t.eopc(c.n_ops),
        t.gops(c.n_ops, 300.0),
        resources::area_paper_accounting(p.n_fus(), dev),
    );
    let s = scfu::map(&g);
    let h = hls::estimate(&g);
    println!(
        "  baselines: SCFU-SCN {} FUs / {} e-Slices; HLS est {} e-Slices @ {:.0} MHz",
        s.total_fus(),
        s.area_eslices(),
        h.eslices(dev),
        h.fmax_mhz
    );
    // Validate on random inputs through the cycle-accurate pipeline.
    let mut pl = Pipeline::new(&p, 256)?;
    let mut rng = Rng::new(1);
    let packets: Vec<Vec<i32>> = (0..6)
        .map(|_| (0..c.n_inputs).map(|_| rng.range_i64(-100, 100) as i32).collect())
        .collect();
    let out = pl.run(&packets, 20_000)?;
    for (pkt, got) in packets.iter().zip(&out) {
        assert_eq!(got, &eval(&g, pkt), "simulator diverged");
    }
    println!("  cycle-accurate simulation verified on {} packets\n", packets.len());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if let Some(path) = std::env::args().nth(1) {
        // Bring your own kernel.
        let src = std::fs::read_to_string(&path)?;
        analyze(&src)?;
    } else {
        analyze(FIR5)?;
        analyze(HORNER)?;
    }
    Ok(())
}
