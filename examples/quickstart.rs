//! Quickstart: compile a kernel, schedule it onto the overlay, inspect
//! the paper's metrics, and run data through the cycle-accurate
//! simulator.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tmfu_overlay::arch::Pipeline;
use tmfu_overlay::dfg::{eval, Characteristics};
use tmfu_overlay::frontend;
use tmfu_overlay::resources::{self, ZYNQ_Z7020};
use tmfu_overlay::sched::{Program, ScheduleTable, Timing};

fn main() -> anyhow::Result<()> {
    // 1. A compute kernel in the C-expression subset (the paper's
    //    Fig. 1 'gradient' benchmark).
    let src = r#"
        kernel gradient(r0, r1, r2, r3, r4) {
            d0 = r0 - r2;  d1 = r1 - r2;  d2 = r2 - r3;  d3 = r2 - r4;
            q0 = d0 * d0;  q1 = d1 * d1;  q2 = d2 * d2;  q3 = d3 * d3;
            s0 = q0 + q1;  s1 = q2 + q3;
            return s0 + s1;
        }
    "#;

    // 2. Frontend: HLL -> DFG (normalized: const-fold, CSE, DCE).
    let g = frontend::compile(src).map_err(|e| anyhow::anyhow!("{e}"))?;
    let c = Characteristics::of(&g);
    println!(
        "DFG '{}': {} inputs, {} ops, depth {} (paper Fig. 1b)",
        g.name, c.n_inputs, c.n_ops, c.depth
    );

    // 3. Scheduler: ASAP stages -> per-FU instruction streams.
    let p = Program::schedule(&g)?;
    let t = Timing::of(&p);
    println!(
        "schedule: {} FUs, II = {} cycles, packet latency = {} cycles",
        p.n_fus(),
        t.ii,
        t.latency()
    );
    let img = p.context_image()?;
    println!(
        "context: {} instruction words = {} bytes; switch-in at 300 MHz = {:.2} us",
        img.n_instrs(),
        img.size_bytes_instr_only(),
        img.switch_time_us(300.0).map_err(|e| anyhow::anyhow!("{e}"))?
    );
    let area = resources::area_paper_accounting(p.n_fus(), &ZYNQ_Z7020);
    println!("area: {} e-Slices ({} FUs x 141)", area, p.n_fus());

    // 4. The first cycles of the paper's Table I.
    println!("\n{}", ScheduleTable::generate(&p, 24).render());

    // 5. Cycle-accurate execution vs the functional oracle.
    let mut pipeline = Pipeline::new(&p, 256)?;
    let packets: Vec<Vec<i32>> = vec![vec![3, 5, 2, 7, 1], vec![10, 20, 30, 40, 50]];
    let out = pipeline.run(&packets, 10_000)?;
    for (pkt, got) in packets.iter().zip(&out) {
        let want = eval(&g, pkt);
        println!("packet {pkt:?} -> {got:?} (oracle {want:?})");
        assert_eq!(got, &want);
    }
    println!("\ncycle-accurate simulation matches the functional oracle — done.");
    Ok(())
}
